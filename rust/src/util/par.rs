//! Minimal data-parallel primitives on std threads.
//!
//! The build is fully offline (no rayon), so we implement the two shapes of
//! parallelism the solver needs — index-parallel fill and index-parallel
//! max-reduce — on `std::thread::scope` with static chunking. Work items
//! are feature columns, which are numerous (p up to ~10⁶) and uniform
//! enough that static chunking is within noise of work stealing here.
//!
//! Thread count: `CELER_NUM_THREADS` env var, else
//! `std::thread::available_parallelism()`.

use std::sync::OnceLock;

/// Below this many items the serial path is used (thread spawn ≈ 10µs
/// dwarfs the per-column work on small problems).
const PAR_THRESHOLD: usize = 8192;

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("CELER_NUM_THREADS") {
            if let Ok(v) = s.parse::<usize>() {
                return v.max(1);
            }
        }
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    })
}

/// `out[i] = f(i)` for all i, in parallel when `out` is large.
pub fn par_fill<F>(out: &mut [f64], f: F)
where
    F: Fn(usize) -> f64 + Sync,
{
    let n = out.len();
    let threads = num_threads();
    if n < PAR_THRESHOLD || threads <= 1 {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (c, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = c * chunk;
                for (k, o) in slice.iter_mut().enumerate() {
                    *o = f(base + k);
                }
            });
        }
    });
}

/// `max_i f(i)` over `0..n` (−∞ for n = 0), in parallel when n is large.
pub fn par_max<F>(n: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    let threads = num_threads();
    if n < PAR_THRESHOLD || threads <= 1 {
        let mut m = f64::NEG_INFINITY;
        for i in 0..n {
            m = m.max(f(i));
        }
        return m;
    }
    let chunk = n.div_ceil(threads);
    let mut partials = vec![f64::NEG_INFINITY; n.div_ceil(chunk)];
    std::thread::scope(|s| {
        for (c, out) in partials.iter_mut().enumerate() {
            let f = &f;
            s.spawn(move || {
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(n);
                let mut m = f64::NEG_INFINITY;
                for i in lo..hi {
                    m = m.max(f(i));
                }
                *out = m;
            });
        }
    });
    partials.into_iter().fold(f64::NEG_INFINITY, f64::max)
}

/// `sum_i f(i)` over `0..n`, in parallel when n is large.
pub fn par_sum<F>(n: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    let threads = num_threads();
    if n < PAR_THRESHOLD || threads <= 1 {
        let mut acc = 0.0;
        for i in 0..n {
            acc += f(i);
        }
        return acc;
    }
    let chunk = n.div_ceil(threads);
    let mut partials = vec![0.0; n.div_ceil(chunk)];
    std::thread::scope(|s| {
        for (c, out) in partials.iter_mut().enumerate() {
            let f = &f;
            s.spawn(move || {
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(n);
                let mut acc = 0.0;
                for i in lo..hi {
                    acc += f(i);
                }
                *out = acc;
            });
        }
    });
    partials.into_iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_small_and_large() {
        for n in [0usize, 3, 100, PAR_THRESHOLD + 17] {
            let mut out = vec![0.0; n];
            par_fill(&mut out, |i| (i * 2) as f64);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, (i * 2) as f64);
            }
        }
    }

    #[test]
    fn max_matches_serial() {
        let n = PAR_THRESHOLD + 1234;
        let f = |i: usize| ((i * 7919) % 104729) as f64;
        let serial = (0..n).map(f).fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(par_max(n, f), serial);
        assert_eq!(par_max(0, f), f64::NEG_INFINITY);
        assert_eq!(par_max(5, |i| i as f64), 4.0);
    }

    #[test]
    fn sum_matches_serial() {
        let n = PAR_THRESHOLD + 55;
        let serial: f64 = (0..n).map(|i| i as f64).sum();
        assert!((par_sum(n, |i| i as f64) - serial).abs() < 1e-6);
        assert_eq!(par_sum(0, |i| i as f64), 0.0);
    }

    #[test]
    fn threads_positive() {
        assert!(num_threads() >= 1);
    }
}
