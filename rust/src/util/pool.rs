//! Persistent sharded worker pool for the column-parallel hot path.
//!
//! Every duality-gap check, Gap Safe screening pass and working-set
//! build reduces over all p columns (`xt_vec`, the KKT violation scan —
//! Eq. 4 / Alg. 2 of the paper), and on p ~ 10⁶ problems these full-p
//! scans dominate wall time once the inner CD epochs are restricted to
//! small working sets. The previous `util::par` implementation spawned
//! and joined fresh OS threads on *every* call via `std::thread::scope`
//! — ~10µs of spawn latency plus cold caches per gap check.
//!
//! This module replaces the per-call spawn with one process-wide pool:
//!
//! - **Lifecycle**: the pool is created lazily on the first parallel
//!   call ([`global`]), spawns `num_threads() − 1` long-lived workers
//!   (the submitting thread is the remaining executor), and is never
//!   torn down — idle workers park on a condvar and cost nothing.
//! - **Jobs**: a job is a closure `f(shard)` plus a shard count. Shards
//!   are claimed dynamically off one atomic counter, so load imbalance
//!   between column shards (e.g. CSC columns of varying nnz) is
//!   absorbed without static chunk tuning. The submitter participates
//!   in the claim loop and blocks until the job completes, which is
//!   what makes borrowing non-`'static` closures sound.
//! - **Nesting policy**: a job's closure must never submit to the pool
//!   (the slot it would wait for is its own). Workers therefore run
//!   inside [`crate::util::par::run_serial`], which makes any nested
//!   `par_*` call take the serial path; [`WorkerPool::run`] itself also
//!   degrades to inline execution inside a serial scope. The
//!   coordinator applies the same policy to its grid workers — see
//!   `coordinator::scheduler`.
//!
//! Shard *semantics* (fixed shard grid, deterministic reduction folds)
//! live one level up in [`crate::util::par`]; the pool only executes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// A shard closure as submitted: executed as `f(shard_index)`.
type ShardFn<'a> = &'a (dyn Fn(usize) + Sync);

/// Lifetime-erased [`ShardFn`] as stored in the job slot.
type ShardFnPtr = *const (dyn Fn(usize) + Sync);

/// A shard-claim job: a type-erased borrow of the submitter's closure.
///
/// The raw pointer erases the closure's lifetime. This is sound because
/// [`WorkerPool::run`] does not return until `done_seq` reaches the
/// job's sequence number, which in turn requires every claimed shard to
/// have finished executing — no worker can touch the pointer after the
/// borrow ends.
#[derive(Clone, Copy)]
struct Job {
    f: ShardFnPtr,
    n_shards: usize,
    seq: u64,
}

// SAFETY: the pointee is `Sync` (shared execution is the point) and the
// submit protocol keeps it alive for as long as any worker can reach it.
unsafe impl Send for Job {}

#[derive(Default)]
struct State {
    /// Sequence number of the most recently published job.
    seq: u64,
    /// Sequence number of the most recently *completed* job.
    done_seq: u64,
    /// Executors (workers AND the submitter, which joins at publish
    /// time) currently inside a job's claim loop. Counters are only
    /// reset for a new job once this drains to zero, so a descheduled
    /// executor can never claim shards against the next job's counter
    /// — without the submitter counted here, a delayed submitter could
    /// steal the next job's shards and run its own stale closure on
    /// them.
    running: usize,
    job: Option<Job>,
    /// Seqs of jobs in which a shard closure panicked; each submitter
    /// removes (and re-raises) its own seq, so a panic is attributed to
    /// the job that caused it even with concurrent submitters.
    poisoned: Vec<u64>,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// Submitters park here (for the job slot, and for completion).
    done_cv: Condvar,
    /// Next shard index to claim (dynamic load balancing).
    next_shard: AtomicUsize,
    /// Shards fully executed; the executor that completes the last one
    /// retires the job.
    completed: AtomicUsize,
}

/// The persistent worker pool. Obtain via [`global`].
pub struct WorkerPool {
    shared: &'static Shared,
    workers: usize,
}

/// The process-wide pool, created on first use. With
/// `CELER_NUM_THREADS=1` (or a single-core machine) no worker threads
/// are spawned and [`WorkerPool::run`] executes inline.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(WorkerPool::start)
}

impl WorkerPool {
    fn start() -> WorkerPool {
        let workers = crate::util::par::num_threads().saturating_sub(1);
        let shared: &'static Shared = Box::leak(Box::new(Shared {
            state: Mutex::new(State::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next_shard: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
        }));
        for i in 0..workers {
            std::thread::Builder::new()
                .name(format!("celer-pool-{i}"))
                .spawn(move || {
                    // With the `numa-pin` feature, worker i is affined to
                    // CPU i+1 (the submitter keeps CPU 0's default mask),
                    // turning the first-touch page placement of
                    // `par::alloc_first_touch` into a *stable* shard →
                    // socket mapping: the thread that first-touched a
                    // shard keeps sweeping it from the same node. Without
                    // the feature the OS scheduler decides — results are
                    // bit-identical either way, only locality differs.
                    #[cfg(all(feature = "numa-pin", target_os = "linux"))]
                    pin_thread_to_cpu(i + 1);
                    worker_loop(shared)
                })
                .expect("spawn pool worker");
        }
        WorkerPool { shared, workers }
    }

    /// Number of pool worker threads (excluding the submitting thread).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute `f(s)` for every shard `s in 0..n_shards`, blocking until
    /// all shards have run. Shards are claimed dynamically by the pool
    /// workers *and* the calling thread.
    ///
    /// Inside a serial scope ([`crate::util::par::run_serial`]) or with
    /// no workers, the shards run inline on the caller. Panics in `f`
    /// are caught on worker threads and re-raised here after the job
    /// drains, so the pool is never wedged by a panicking closure.
    pub fn run(&self, n_shards: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_shards == 0 {
            return;
        }
        if self.workers == 0 || crate::util::par::in_serial_scope() {
            for s in 0..n_shards {
                f(s);
            }
            return;
        }
        // Erase the closure's lifetime; see `Job` for why this is sound.
        let f_ptr = unsafe { std::mem::transmute::<ShardFn<'_>, ShardFnPtr>(f) };
        let seq;
        {
            let mut st = self.shared.state.lock().unwrap();
            // Wait for the job slot AND for stragglers of the previous
            // job to leave their claim loops before resetting counters.
            while st.job.is_some() || st.running > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.seq += 1;
            seq = st.seq;
            self.shared.next_shard.store(0, Ordering::Relaxed);
            self.shared.completed.store(0, Ordering::Relaxed);
            // The submitter is an executor too: it joins `running` while
            // the job is published, so the claim counters cannot be
            // reset for a successor job while this thread could still be
            // inside its claim loop below.
            st.running += 1;
            st.job = Some(Job { f: f_ptr, n_shards, seq });
            self.shared.work_cv.notify_all();
        }
        // The submitter's shard execution runs in a serial scope like
        // the workers', so a shard closure that reaches back into
        // `par_*` degrades to the serial path instead of submitting a
        // nested job (which would deadlock on the occupied job slot).
        crate::util::par::run_serial(|| run_shards(self.shared, f_ptr, n_shards, seq));
        let mut st = self.shared.state.lock().unwrap();
        st.running -= 1;
        if st.running == 0 && st.job.is_none() {
            // Last executor out: successors waiting to reuse the claim
            // counters may proceed.
            self.shared.done_cv.notify_all();
        }
        while st.done_seq < seq {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        let panicked = st.poisoned.iter().any(|&q| q == seq);
        if panicked {
            st.poisoned.retain(|&q| q != seq);
        }
        drop(st);
        if panicked {
            panic!("celer worker pool: a parallel shard closure panicked");
        }
    }
}

/// Claim-and-execute loop shared by workers and the submitting thread.
fn run_shards(shared: &Shared, f: ShardFnPtr, n_shards: usize, seq: u64) {
    loop {
        let s = shared.next_shard.fetch_add(1, Ordering::Relaxed);
        if s >= n_shards {
            return;
        }
        // SAFETY: a successful claim (s < n_shards) proves the job is
        // not yet complete — shard s has never run, `completed` cannot
        // reach n_shards without it, so the submitter is still blocked
        // in `run` and the closure borrow behind `f` is alive.
        let f = unsafe { &*f };
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(s))).is_err() {
            shared.state.lock().unwrap().poisoned.push(seq);
        }
        // AcqRel: the final increment's reader synchronizes with every
        // shard executor's writes before the submitter observes "done".
        if shared.completed.fetch_add(1, Ordering::AcqRel) + 1 == n_shards {
            let mut st = shared.state.lock().unwrap();
            st.job = None;
            st.done_seq = seq;
            drop(st);
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &'static Shared) {
    // Nested `par_*` calls from inside a shard closure must not submit
    // back to the pool (self-deadlock); run the whole worker in a
    // serial scope so they take the serial path instead.
    crate::util::par::run_serial(|| {
        let mut last_seen = 0u64;
        loop {
            let job = {
                let mut st = shared.state.lock().unwrap();
                loop {
                    match st.job {
                        Some(j) if j.seq != last_seen => {
                            st.running += 1;
                            break j;
                        }
                        _ => st = shared.work_cv.wait(st).unwrap(),
                    }
                }
            };
            last_seen = job.seq;
            run_shards(shared, job.f, job.n_shards, job.seq);
            let mut st = shared.state.lock().unwrap();
            st.running -= 1;
            if st.running == 0 && st.job.is_none() {
                // Last straggler out: submitters waiting to reuse the
                // claim counters may proceed.
                shared.done_cv.notify_all();
            }
        }
    });
}

/// Best-effort thread affinity via `sched_setaffinity(2)` — no libc
/// crate in the offline build, so the one syscall wrapper we need is
/// declared directly. The mask covers 1024 CPUs (the kernel's default
/// `cpu_set_t` width); `cpu` wraps modulo the machine's parallelism so a
/// pool wider than the box still pins validly. Errors are ignored: an
/// affinity failure (cpuset restrictions, exotic kernels) must never
/// take down a worker — the pool is merely unpinned, as without the
/// feature.
#[cfg(all(feature = "numa-pin", target_os = "linux"))]
fn pin_thread_to_cpu(cpu: usize) {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let ncpus = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let cpu = cpu % ncpus.max(1);
    let mut mask = [0u64; 16]; // 1024-bit cpu_set_t
    if cpu / 64 < mask.len() {
        mask[cpu / 64] = 1u64 << (cpu % 64);
        // pid 0 = the calling thread. Best effort: ignore the result.
        unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    }
}

/// A `Sync` wrapper for a raw mutable pointer handed to shard closures.
///
/// Writers must guarantee disjointness: each index (or index range) is
/// written by exactly one shard. Used by `util::par` for partial-result
/// slots and output buffers, and by `solvers::batch` for the
/// lane-sharded sweep.
#[derive(Clone, Copy)]
pub(crate) struct SyncPtr<T>(pub *mut T);

// SAFETY: shard-disjoint writes only; see the struct docs.
unsafe impl<T> Sync for SyncPtr<T> {}
unsafe impl<T> Send for SyncPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_shard_exactly_once() {
        let pool = global();
        for n in [0usize, 1, 3, 64, 257] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, &|s| {
                hits[s].fetch_add(1, Ordering::Relaxed);
            });
            for (s, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "shard {s}");
            }
        }
    }

    #[test]
    fn sequential_jobs_reuse_the_pool() {
        let pool = global();
        let acc = AtomicU64::new(0);
        for round in 0..50u64 {
            pool.run(16, &|s| {
                acc.fetch_add(round + s as u64, Ordering::Relaxed);
            });
        }
        let expect: u64 = (0..50u64).map(|r| 16 * r + (0..16).sum::<u64>()).sum();
        assert_eq!(acc.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn serial_scope_runs_inline() {
        let pool = global();
        let hits = AtomicUsize::new(0);
        crate::util::par::run_serial(|| {
            pool.run(8, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn concurrent_submitters_serialize_safely() {
        // Several foreign threads (as in `cargo test`'s own parallelism)
        // submitting at once must each see exactly their own job done.
        let pool = global();
        std::thread::scope(|sc| {
            for t in 0..4usize {
                sc.spawn(move || {
                    let count = AtomicUsize::new(0);
                    pool.run(32 + t, &|_| {
                        count.fetch_add(1, Ordering::Relaxed);
                    });
                    assert_eq!(count.load(Ordering::Relaxed), 32 + t);
                });
            }
        });
    }
}
