//! Selection utilities for working-set construction.
//!
//! CELER ranks features by the Gap-Safe score `d_j(θ)` and keeps the `p_t`
//! smallest. Doing a full O(p log p) sort every outer iteration is wasteful
//! for p ~ 10⁶, so we use an in-place quickselect (Hoare partition with
//! median-of-three pivots) that runs in expected O(p).

/// Return the indices of the `k` smallest values of `scores`
/// (ties broken arbitrarily). The returned indices are NOT sorted by score.
pub fn k_smallest_indices(scores: &[f64], k: usize) -> Vec<usize> {
    let p = scores.len();
    if k == 0 {
        return Vec::new();
    }
    if k >= p {
        return (0..p).collect();
    }
    let mut idx: Vec<usize> = (0..p).collect();
    quickselect(&mut idx, scores, k);
    idx.truncate(k);
    idx
}

/// Partially order `idx` so that the first `k` entries hold the k smallest
/// scores.
fn quickselect(idx: &mut [usize], scores: &[f64], k: usize) {
    let mut lo = 0usize;
    let mut hi = idx.len();
    let mut k = k;
    while hi - lo > 1 {
        if k == 0 {
            return;
        }
        let pivot = median_of_three(idx, scores, lo, hi);
        let mid = partition(idx, scores, lo, hi, pivot);
        // All elements in [lo, mid) are < pivot-ish; decide which side holds k.
        let left = mid - lo;
        if k < left {
            hi = mid;
        } else if k > left {
            lo = mid;
            k -= left;
        } else {
            return;
        }
    }
}

#[inline]
fn median_of_three(idx: &[usize], scores: &[f64], lo: usize, hi: usize) -> f64 {
    let a = scores[idx[lo]];
    let b = scores[idx[lo + (hi - lo) / 2]];
    let c = scores[idx[hi - 1]];
    // median of a, b, c
    if (a <= b) == (b <= c) {
        b
    } else if (b <= a) == (a <= c) {
        a
    } else {
        c
    }
}

/// Hoare-style partition around value `pivot`; returns split point `mid`
/// such that scores[idx[lo..mid]] <= pivot <= scores[idx[mid..hi]] with
/// guaranteed progress (mid strictly inside (lo, hi)).
fn partition(idx: &mut [usize], scores: &[f64], lo: usize, hi: usize, pivot: f64) -> usize {
    let mut i = lo;
    let mut j = hi - 1;
    loop {
        while scores[idx[i]] < pivot {
            i += 1;
        }
        while scores[idx[j]] > pivot {
            j -= 1;
        }
        if i >= j {
            // ensure progress: split must be in (lo, hi)
            let mid = j + 1;
            return mid.clamp(lo + 1, hi - 1);
        }
        idx.swap(i, j);
        i += 1;
        if j == 0 {
            return lo + 1;
        }
        j -= 1;
    }
}

/// Argsort of `scores` ascending (stable). Full sort — only used on small
/// arrays (tests, reports).
pub fn argsort(scores: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_k_smallest(scores: &[f64], k: usize) {
        let got = k_smallest_indices(scores, k);
        assert_eq!(got.len(), k.min(scores.len()));
        let mut sorted = scores.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if k == 0 || k >= scores.len() {
            return;
        }
        let thresh = sorted[k - 1];
        // every selected value must be <= the k-th smallest (ties allowed)
        for &i in &got {
            assert!(
                scores[i] <= thresh + 1e-15,
                "selected {} > threshold {}",
                scores[i],
                thresh
            );
        }
        // and no duplicates
        let mut g = got.clone();
        g.sort();
        g.dedup();
        assert_eq!(g.len(), k);
    }

    #[test]
    fn small_cases() {
        check_k_smallest(&[3.0, 1.0, 2.0], 0);
        check_k_smallest(&[3.0, 1.0, 2.0], 1);
        check_k_smallest(&[3.0, 1.0, 2.0], 2);
        check_k_smallest(&[3.0, 1.0, 2.0], 3);
        check_k_smallest(&[3.0, 1.0, 2.0], 5);
        check_k_smallest(&[1.0], 1);
    }

    #[test]
    fn with_ties() {
        let scores = vec![1.0, 1.0, 1.0, 0.5, 0.5, 2.0];
        check_k_smallest(&scores, 2);
        check_k_smallest(&scores, 3);
        check_k_smallest(&scores, 4);
    }

    #[test]
    fn random_stress() {
        let mut rng = Rng::new(77);
        for trial in 0..50 {
            let n = 1 + rng.below(500);
            let scores: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let k = rng.below(n + 1);
            check_k_smallest(&scores, k);
            let _ = trial;
        }
    }

    #[test]
    fn all_equal() {
        let scores = vec![2.5; 100];
        check_k_smallest(&scores, 37);
    }

    #[test]
    fn sorted_and_reversed_inputs() {
        let asc: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let desc: Vec<f64> = (0..200).map(|i| -(i as f64)).collect();
        check_k_smallest(&asc, 50);
        check_k_smallest(&desc, 50);
    }

    #[test]
    fn argsort_orders() {
        let s = vec![3.0, -1.0, 2.0];
        assert_eq!(argsort(&s), vec![1, 2, 0]);
    }
}
