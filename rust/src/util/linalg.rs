//! Small dense linear algebra used by dual extrapolation.
//!
//! The extrapolation system `(UᵀU) z = 1_K` is only K×K (K = 5 by default),
//! so a hand-rolled Gaussian elimination with partial pivoting is both
//! sufficient and dependency-free. The same routine is mirrored in the JAX
//! layer (`python/compile/model.py::gauss_solve`) because LAPACK
//! custom-calls are not available in the standalone PJRT runtime.

/// Euclidean dot product, routed through the width-8 multi-accumulator
/// kernel of [`crate::util::simd`].
///
/// Perf note (supersedes the PR-1 note that kept the naive loop): the
/// earlier 4-accumulator experiment interleaved accumulators with a
/// strided access pattern the vectorizer could not coalesce. The
/// `simd::dot` layout — contiguous `chunks_exact(8)` with one
/// accumulator per in-chunk lane and a pairwise reduction tree —
/// vectorizes cleanly (verified on the `-O3` C mirror in
/// `scripts/simd_proxy.c`; see BENCH_6.json). Reduction order changes
/// versus the naive loop, but every bitwise pin in the repo compares
/// two paths that share these kernels, so the contract in
/// `util/simd.rs` is the single source of truth for reduction order.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::util::simd::dot(a, b)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// BLAS-named alias for [`norm`].
#[inline]
pub fn nrm2(a: &[f64]) -> f64 {
    norm(a)
}

/// ℓ1 norm `Σ |aᵢ|` (width-8 accumulator fold).
#[inline]
pub fn asum(a: &[f64]) -> f64 {
    crate::util::simd::asum(a)
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    crate::util::simd::axpy(alpha, x, y)
}

/// out = b − a (element-wise; the extrapolation ring's residual diffs).
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    crate::util::simd::sub(a, b, out)
}

/// Squared Euclidean distance.
#[inline]
pub fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Solve the dense K×K system `A z = b` in place via Gaussian elimination
/// with partial pivoting. `a` is row-major K×K and is destroyed.
///
/// Returns `None` when the system is numerically singular (a pivot smaller
/// than `tol * max|A|`), which callers treat as the paper's §5
/// ill-conditioning signal (fall back to `θ_res` rather than regularize).
pub fn solve_in_place(a: &mut [f64], b: &mut [f64], k: usize, tol: f64) -> Option<Vec<f64>> {
    debug_assert_eq!(a.len(), k * k);
    debug_assert_eq!(b.len(), k);
    let scale = a.iter().fold(0.0f64, |m, &v| m.max(v.abs())).max(1e-300);
    let threshold = tol * scale;
    for col in 0..k {
        // partial pivot
        let mut piv = col;
        let mut best = a[col * k + col].abs();
        for r in (col + 1)..k {
            let v = a[r * k + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best <= threshold {
            return None;
        }
        if piv != col {
            for c in 0..k {
                a.swap(col * k + c, piv * k + c);
            }
            b.swap(col, piv);
        }
        let inv = 1.0 / a[col * k + col];
        for r in (col + 1)..k {
            let f = a[r * k + col] * inv;
            if f == 0.0 {
                continue;
            }
            for c in col..k {
                a[r * k + c] -= f * a[col * k + c];
            }
            b[r] -= f * b[col];
        }
    }
    // back substitution
    let mut z = vec![0.0; k];
    for row in (0..k).rev() {
        let mut acc = b[row];
        for c in (row + 1)..k {
            acc -= a[row * k + c] * z[c];
        }
        z[row] = acc / a[row * k + row];
    }
    Some(z)
}

/// Solve `A z = b` without destroying inputs.
pub fn solve(a: &[f64], b: &[f64], k: usize, tol: f64) -> Option<Vec<f64>> {
    let mut aa = a.to_vec();
    let mut bb = b.to_vec();
    solve_in_place(&mut aa, &mut bb, k, tol)
}

/// Eigendecomposition of a symmetric K×K matrix by the cyclic Jacobi
/// method. Returns (eigenvalues, eigenvectors) with `vecs[i*k + j]` =
/// component i of eigenvector j (column-major eigenvectors).
pub fn sym_eigen(a: &[f64], k: usize) -> (Vec<f64>, Vec<f64>) {
    debug_assert_eq!(a.len(), k * k);
    let mut m = a.to_vec();
    // v = identity
    let mut v = vec![0.0; k * k];
    for i in 0..k {
        v[i * k + i] = 1.0;
    }
    for _sweep in 0..100 {
        // max off-diagonal magnitude
        let mut off = 0.0f64;
        for i in 0..k {
            for j in (i + 1)..k {
                off = off.max(m[i * k + j].abs());
            }
        }
        let scale = (0..k).fold(0.0f64, |s, i| s.max(m[i * k + i].abs())).max(1e-300);
        if off <= 1e-15 * scale {
            break;
        }
        for p in 0..k {
            for q in (p + 1)..k {
                let apq = m[p * k + q];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let (app, aqq) = (m[p * k + p], m[q * k + q]);
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for i in 0..k {
                    let (aip, aiq) = (m[i * k + p], m[i * k + q]);
                    m[i * k + p] = c * aip - s * aiq;
                    m[i * k + q] = s * aip + c * aiq;
                }
                for i in 0..k {
                    let (api, aqi) = (m[p * k + i], m[q * k + i]);
                    m[p * k + i] = c * api - s * aqi;
                    m[q * k + i] = s * api + c * aqi;
                }
                for i in 0..k {
                    let (vip, viq) = (v[i * k + p], v[i * k + q]);
                    v[i * k + p] = c * vip - s * viq;
                    v[i * k + q] = s * vip + c * viq;
                }
            }
        }
    }
    let vals: Vec<f64> = (0..k).map(|i| m[i * k + i]).collect();
    (vals, v)
}

/// Minimize `cᵀ G c` subject to `1ᵀ c = 1` for a symmetric PSD Gram
/// matrix G (the dual-extrapolation objective of Scieur et al. 2016).
///
/// When G is invertible this equals the paper's `c = z/(zᵀ1)` with
/// `Gz = 1`; when G is singular (converged or collinear residual
/// sequences) the solution is computed on the non-null eigenspace, which
/// is what makes extrapolation exact on degenerate trajectories (Fig. 1's
/// 2-D toy). Returns `None` only when every direction is null or the
/// result is non-finite.
pub fn min_quadratic_on_simplex_affine(g: &[f64], k: usize) -> Option<Vec<f64>> {
    let (vals, vecs) = sym_eigen(g, k);
    let vmax = vals.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    if vmax <= 0.0 {
        // G = 0: any c works; pick uniform weights.
        return Some(vec![1.0 / k as f64; k]);
    }
    let cut = 1e-13 * vmax;
    // Solve min over c = V y: Σ λ_i y_i² s.t. (Vᵀ1)ᵀ y = 1.
    // Null directions (λ_i ≈ 0) absorb the constraint for free: if any
    // null direction has (Vᵀ1)_i ≠ 0, the minimum is 0 along it.
    let mut w = vec![0.0; k]; // w = Vᵀ1
    for i in 0..k {
        let mut acc = 0.0;
        for r in 0..k {
            acc += vecs[r * k + i];
        }
        w[i] = acc;
    }
    // Prefer exact-null solution: project 1 onto null space.
    let mut null_sq = 0.0;
    for i in 0..k {
        if vals[i].abs() <= cut {
            null_sq += w[i] * w[i];
        }
    }
    let mut y = vec![0.0; k];
    if null_sq > 1e-20 {
        // y_i = w_i / null_sq on null directions → objective exactly 0
        for i in 0..k {
            if vals[i].abs() <= cut {
                y[i] = w[i] / null_sq;
            }
        }
    } else {
        // classic KKT: y_i = μ w_i / λ_i with μ = 1 / Σ w_i²/λ_i
        let mut denom = 0.0;
        for i in 0..k {
            if vals[i].abs() > cut {
                denom += w[i] * w[i] / vals[i];
            }
        }
        if denom.abs() < 1e-300 {
            return None;
        }
        let mu = 1.0 / denom;
        for i in 0..k {
            if vals[i].abs() > cut {
                y[i] = mu * w[i] / vals[i];
            }
        }
    }
    // c = V y
    let mut c = vec![0.0; k];
    for r in 0..k {
        let mut acc = 0.0;
        for i in 0..k {
            acc += vecs[r * k + i] * y[i];
        }
        c[r] = acc;
    }
    if !c.iter().all(|v| v.is_finite()) {
        return None;
    }
    // renormalize to kill rounding drift on the constraint
    let s: f64 = c.iter().sum();
    if s.abs() < 1e-12 {
        return None;
    }
    for v in c.iter_mut() {
        *v /= s;
    }
    Some(c)
}

/// Gram matrix `UᵀU` of a column-major n×k matrix stored as k columns.
pub fn gram(cols: &[&[f64]]) -> Vec<f64> {
    let k = cols.len();
    let mut g = vec![0.0; k * k];
    for i in 0..k {
        for j in i..k {
            let v = dot(cols[i], cols[j]);
            g[i * k + j] = v;
            g[j * k + i] = v;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_norm_axpy() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert!((norm(&a) - 14f64.sqrt()).abs() < 1e-12);
        assert_eq!(nrm2(&a), norm(&a));
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
    }

    #[test]
    fn asum_sub() {
        let a = [1.0, -2.0, 3.0, -4.0];
        assert_eq!(asum(&a), 10.0);
        let b = [2.0, 2.0, 2.0, 2.0];
        let mut d = [0.0; 4];
        sub(&a, &b, &mut d);
        assert_eq!(d, [1.0, 4.0, -1.0, 6.0]);
    }

    #[test]
    fn solve_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![3.0, 4.0];
        let z = solve(&a, &b, 2, 1e-12).unwrap();
        assert_eq!(z, vec![3.0, 4.0]);
    }

    #[test]
    fn solve_general_3x3() {
        // A = [[2,1,1],[1,3,2],[1,0,0]], b = [4,5,6] -> z = [6, 15, -23]
        let a = vec![2.0, 1.0, 1.0, 1.0, 3.0, 2.0, 1.0, 0.0, 0.0];
        let b = vec![4.0, 5.0, 6.0];
        let z = solve(&a, &b, 3, 1e-12).unwrap();
        assert!((z[0] - 6.0).abs() < 1e-9, "{z:?}");
        assert!((z[1] - 15.0).abs() < 1e-9);
        assert!((z[2] + 23.0).abs() < 1e-9);
    }

    #[test]
    fn solve_needs_pivoting() {
        // zero top-left pivot forces a row swap
        let a = vec![0.0, 1.0, 1.0, 0.0];
        let b = vec![2.0, 3.0];
        let z = solve(&a, &b, 2, 1e-12).unwrap();
        assert_eq!(z, vec![3.0, 2.0]);
    }

    #[test]
    fn solve_singular_returns_none() {
        let a = vec![1.0, 2.0, 2.0, 4.0];
        let b = vec![1.0, 2.0];
        assert!(solve(&a, &b, 2, 1e-10).is_none());
    }

    #[test]
    fn solve_residual_small_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(123);
        for k in 1..=6 {
            let a: Vec<f64> = (0..k * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            if let Some(z) = solve(&a, &b, k, 1e-12) {
                for r in 0..k {
                    let mut acc = 0.0;
                    for c in 0..k {
                        acc += a[r * k + c] * z[c];
                    }
                    assert!((acc - b[r]).abs() < 1e-8, "k={k} r={r}");
                }
            }
        }
    }

    #[test]
    fn sym_eigen_diag() {
        let a = vec![3.0, 0.0, 0.0, 1.0];
        let (vals, vecs) = sym_eigen(&a, 2);
        let mut v = vals.clone();
        v.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((v[0] - 1.0).abs() < 1e-12);
        assert!((v[1] - 3.0).abs() < 1e-12);
        // eigenvectors orthonormal
        let dot01 = vecs[0] * vecs[1] + vecs[2] * vecs[3];
        assert!(dot01.abs() < 1e-12);
    }

    #[test]
    fn sym_eigen_reconstructs() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(8);
        let k = 5;
        // random symmetric
        let mut a = vec![0.0; k * k];
        for i in 0..k {
            for j in i..k {
                let v = rng.normal();
                a[i * k + j] = v;
                a[j * k + i] = v;
            }
        }
        let (vals, vecs) = sym_eigen(&a, k);
        // A v_j = λ_j v_j
        for j in 0..k {
            for i in 0..k {
                let mut av = 0.0;
                for t in 0..k {
                    av += a[i * k + t] * vecs[t * k + j];
                }
                assert!(
                    (av - vals[j] * vecs[i * k + j]).abs() < 1e-9,
                    "eigenpair {j} row {i}"
                );
            }
        }
    }

    #[test]
    fn constrained_min_invertible_matches_paper_formula() {
        // G invertible: c must equal z/(z^T 1) with Gz = 1.
        let g = vec![2.0, 0.5, 0.5, 1.0];
        let c = min_quadratic_on_simplex_affine(&g, 2).unwrap();
        let z = solve(&g, &[1.0, 1.0], 2, 1e-14).unwrap();
        let s: f64 = z.iter().sum();
        for i in 0..2 {
            assert!((c[i] - z[i] / s).abs() < 1e-10, "{c:?} vs {z:?}");
        }
        assert!((c.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn constrained_min_rank_deficient() {
        // G = g g^T (rank 1), g = (1, ρ): the minimizer zeroes the
        // quadratic exactly: c1 + ρ c2 = 0, c1 + c2 = 1.
        let rho = 0.6;
        let g = vec![1.0, rho, rho, rho * rho];
        let c = min_quadratic_on_simplex_affine(&g, 2).unwrap();
        assert!((c[0] + rho * c[1]).abs() < 1e-10, "{c:?}");
        assert!((c[0] + c[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn constrained_min_zero_matrix() {
        let g = vec![0.0; 9];
        let c = min_quadratic_on_simplex_affine(&g, 3).unwrap();
        assert!((c.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gram_symmetric_psd_diag() {
        let c1 = vec![1.0, 2.0, 3.0];
        let c2 = vec![0.0, 1.0, -1.0];
        let g = gram(&[&c1, &c2]);
        assert_eq!(g.len(), 4);
        assert_eq!(g[1], g[2]);
        assert!((g[0] - 14.0).abs() < 1e-12);
        assert!((g[3] - 2.0).abs() < 1e-12);
    }
}
