//! Shared utilities: deterministic RNG, small linear algebra, selection,
//! and the robustness layer's error/fault vocabulary.

pub mod error;
pub mod fault;
pub mod json;
pub mod linalg;
pub mod par;
pub mod pool;
pub mod rng;
pub mod select;
pub mod simd;

/// Soft-thresholding operator `ST(x, u) = sign(x) · max(0, |x| − u)`.
#[inline(always)]
pub fn soft_threshold(x: f64, u: f64) -> f64 {
    if x > u {
        x - u
    } else if x < -u {
        x + u
    } else {
        0.0
    }
}

/// f32 soft-thresholding (the f32 sweep mode's inner update).
#[inline(always)]
pub fn soft_threshold_f32(x: f32, u: f32) -> f32 {
    if x > u {
        x - u
    } else if x < -u {
        x + u
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::soft_threshold;

    #[test]
    fn st_basic() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
        assert_eq!(soft_threshold(0.0, 0.0), 0.0);
    }

    #[test]
    fn st_shrinks_toward_zero() {
        for &x in &[-5.0, -0.1, 0.0, 0.1, 5.0] {
            let y = soft_threshold(x, 0.3);
            assert!(y.abs() <= x.abs());
            assert!(x * y >= 0.0, "sign preserved or zero");
        }
    }
}
