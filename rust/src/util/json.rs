//! Minimal JSON parser + writer (offline build: no serde).
//!
//! Supports the full JSON value grammar minus exotic number forms; used
//! for the AOT artifact manifest and report dumps. Not a general-purpose
//! replacement for serde — inputs are trusted build artifacts.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Parse a JSON document.
pub fn parse(s: &str) -> anyhow::Result<Json> {
    let bytes = s.as_bytes();
    let mut pos = 0;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        anyhow::bail!("trailing characters at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> anyhow::Result<()> {
    skip_ws(b, pos);
    if *pos >= b.len() || b[*pos] != c {
        anyhow::bail!("expected {:?} at byte {}", c as char, *pos);
    }
    *pos += 1;
    Ok(())
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        anyhow::bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> anyhow::Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        anyhow::bail!("bad literal at byte {}", *pos)
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            anyhow::bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            c => {
                // raw UTF-8 passthrough
                let len = utf8_len(c);
                out.push_str(std::str::from_utf8(&b[*pos..*pos + len])?);
                *pos += len;
            }
        }
    }
    anyhow::bail!("unterminated string")
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    expect(b, pos, b'[')?;
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos >= b.len() {
            anyhow::bail!("unterminated array");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            c => anyhow::bail!("expected , or ] got {:?}", c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    expect(b, pos, b'{')?;
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        if *pos >= b.len() {
            anyhow::bail!("unterminated object");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            c => anyhow::bail!("expected , or }} got {:?}", c as char),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"artifacts":[{"file":"a.hlo.txt","n":48,"op":"inner_solve","w":64}],"version":1}"#;
        let v = parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(parse(&printed).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = parse("{\"n\": 48}").unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(48));
        assert_eq!(parse("2.5").unwrap().as_usize(), None);
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }
}
