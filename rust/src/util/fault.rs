//! Deterministic fault-injection harness (feature `fault-inject`).
//!
//! A [`FaultPlan`] is carried *by value inside solver configs* rather
//! than armed through process globals: `cargo test` runs many tests
//! concurrently in one process, and a global one-shot fault could be
//! consumed by a concurrent, unrelated solve — turning the bit-identity
//! pin tests flaky. A config-carried plan is visible only to the solve
//! it was handed to, so injection is exactly reproducible.
//!
//! Without the `fault-inject` feature the plan is a zero-sized no-op:
//! every injection point compiles to nothing, so production binaries
//! pay no branch on the hot path beyond the gap-check-frequency code
//! that already runs there.
//!
//! Injection points (all one-shot — they disarm on first firing so a
//! recovered run cannot be re-poisoned forever):
//! - `inject_nan_residual(epoch, r)`: writes NaN into `r[0]` at the
//!   first gap check with `epoch >= armed_epoch`.
//! - `maybe_panic_shard()`: panics inside a scheduler job closure.
//! - `maybe_delay_worker()`: sleeps inside a scheduler job so the
//!   per-job timeout machinery can observe a slow worker.

#[cfg(feature = "fault-inject")]
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(feature = "fault-inject")]
use std::sync::Arc;

#[cfg(feature = "fault-inject")]
#[derive(Debug, Default)]
struct Inner {
    /// Epoch at which to corrupt the residual; 0 = disarmed.
    nan_residual_epoch: AtomicUsize,
    /// Panic once inside the next scheduler job closure.
    shard_panic: AtomicBool,
    /// Sleep this many milliseconds inside the next scheduler job.
    worker_delay_ms: AtomicU64,
}

/// A deterministic, config-carried set of injection points. `Clone` is
/// shallow (`Arc`), so the plan handed to a config and the one kept by
/// the test observe the same disarm state.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    #[cfg(feature = "fault-inject")]
    inner: Option<Arc<Inner>>,
}

impl FaultPlan {
    /// The inert plan: injects nothing. This is `Default`.
    pub fn none() -> Self {
        FaultPlan::default()
    }
}

#[cfg(feature = "fault-inject")]
impl FaultPlan {
    /// A fresh armed-capable plan (still injects nothing until an
    /// `arm_*` call).
    pub fn armed() -> Self {
        FaultPlan { inner: Some(Arc::new(Inner::default())) }
    }

    /// Corrupt the residual (NaN into `r[0]`) at the first gap check of
    /// epoch ≥ `epoch` (1-based; pass ≥ 1).
    pub fn arm_nan_residual(&self, epoch: usize) {
        let inner = self.inner.as_ref().expect("arm on FaultPlan::none()");
        inner.nan_residual_epoch.store(epoch.max(1), Ordering::SeqCst);
    }

    /// Panic inside the next scheduler job closure that polls this plan.
    pub fn arm_shard_panic(&self) {
        let inner = self.inner.as_ref().expect("arm on FaultPlan::none()");
        inner.shard_panic.store(true, Ordering::SeqCst);
    }

    /// Delay the next scheduler job that polls this plan by `ms`
    /// milliseconds.
    pub fn arm_worker_delay(&self, ms: u64) {
        let inner = self.inner.as_ref().expect("arm on FaultPlan::none()");
        inner.worker_delay_ms.store(ms, Ordering::SeqCst);
    }
}

#[cfg(feature = "fault-inject")]
impl FaultPlan {
    /// One-shot: if armed and `epoch` has been reached, set `r[0] = NaN`
    /// and disarm. Returns whether an injection fired.
    #[inline]
    pub fn inject_nan_residual(&self, epoch: usize, r: &mut [f64]) -> bool {
        let Some(inner) = self.inner.as_ref() else { return false };
        let armed = inner.nan_residual_epoch.load(Ordering::SeqCst);
        if armed == 0 || epoch < armed || r.is_empty() {
            return false;
        }
        // Swap-to-zero makes the shot atomic even if two lanes check
        // the same plan at the same epoch.
        if inner.nan_residual_epoch.swap(0, Ordering::SeqCst) == 0 {
            return false;
        }
        r[0] = f64::NAN;
        true
    }

    /// One-shot: panic if armed (scheduler job body).
    #[inline]
    pub fn maybe_panic_shard(&self) {
        let Some(inner) = self.inner.as_ref() else { return };
        if inner.shard_panic.swap(false, Ordering::SeqCst) {
            panic!("fault-inject: shard panic");
        }
    }

    /// One-shot: sleep if armed (scheduler job body).
    #[inline]
    pub fn maybe_delay_worker(&self) {
        let Some(inner) = self.inner.as_ref() else { return };
        let ms = inner.worker_delay_ms.swap(0, Ordering::SeqCst);
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

#[cfg(not(feature = "fault-inject"))]
impl FaultPlan {
    #[inline(always)]
    pub fn inject_nan_residual(&self, _epoch: usize, _r: &mut [f64]) -> bool {
        false
    }

    #[inline(always)]
    pub fn maybe_panic_shard(&self) {}

    #[inline(always)]
    pub fn maybe_delay_worker(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_plan_injects_nothing() {
        let plan = FaultPlan::none();
        let mut r = vec![1.0, 2.0];
        assert!(!plan.inject_nan_residual(1, &mut r));
        assert_eq!(r, vec![1.0, 2.0]);
        plan.maybe_panic_shard();
        plan.maybe_delay_worker();
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn nan_residual_fires_once_at_epoch() {
        let plan = FaultPlan::armed();
        plan.arm_nan_residual(3);
        let mut r = vec![1.0, 2.0];
        assert!(!plan.inject_nan_residual(2, &mut r), "not yet due");
        assert!(plan.inject_nan_residual(3, &mut r), "fires at epoch 3");
        assert!(r[0].is_nan());
        r[0] = 1.0;
        assert!(!plan.inject_nan_residual(4, &mut r), "one-shot disarmed");
        assert_eq!(r[0], 1.0);
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn clones_share_disarm_state() {
        let plan = FaultPlan::armed();
        plan.arm_shard_panic();
        let seen = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.clone().maybe_panic_shard();
        }));
        assert!(seen.is_err(), "armed clone panics");
        plan.maybe_panic_shard(); // disarmed by the clone: no panic
    }
}
