//! Primal objective for the Lasso: `P(β) = ½‖y − Xβ‖² + λ‖β‖₁`.

use crate::data::design::DesignOps;

/// `½‖r‖² + λ‖β‖₁` from a maintained residual (no matvec).
#[inline]
pub fn primal_from_residual(r: &[f64], beta: &[f64], lambda: f64) -> f64 {
    0.5 * crate::util::linalg::dot(r, r) + lambda * l1_norm(beta)
}

/// Full primal objective (computes the residual).
pub fn primal<D: DesignOps>(x: &D, y: &[f64], beta: &[f64], lambda: f64) -> f64 {
    let mut r = vec![0.0; x.n()];
    residual(x, y, beta, &mut r);
    primal_from_residual(&r, beta, lambda)
}

/// `out = y − Xβ`.
pub fn residual<D: DesignOps>(x: &D, y: &[f64], beta: &[f64], out: &mut [f64]) {
    x.matvec(beta, out);
    for i in 0..y.len() {
        out[i] = y[i] - out[i];
    }
}

/// ℓ1 norm (width-8 accumulator fold; see `util::simd` for the
/// reduction-order contract).
#[inline]
pub fn l1_norm(beta: &[f64]) -> f64 {
    crate::util::linalg::asum(beta)
}

/// Generalized GLM primal `P(β) = F(Xβ) + λ‖β‖₁` from the maintained
/// state: `xw = Xβ` (linear predictor) and `r = −∇F(Xβ)` (generalized
/// residual). The quadratic datafit reads only `r` — for it this is
/// bit-for-bit [`primal_from_residual`]; the GLM fits read only `xw`.
#[inline]
pub fn glm_primal_value<F: crate::datafit::Datafit>(
    datafit: &F,
    y: &[f64],
    xw: &[f64],
    r: &[f64],
    beta: &[f64],
    lambda: f64,
) -> f64 {
    datafit.value(y, xw, r) + lambda * l1_norm(beta)
}

/// Fill the GLM primal state for β: `xw = Xβ` (one matvec) and the
/// generalized residual `r = −∇F(xw)`. The quadratic instance computes
/// the same values as [`residual`] (with the matvec landing in `xw`).
pub fn glm_state<D: DesignOps, F: crate::datafit::Datafit>(
    x: &D,
    datafit: &F,
    y: &[f64],
    beta: &[f64],
    xw: &mut [f64],
    r: &mut [f64],
) {
    x.matvec(beta, xw);
    datafit.fill_residual(y, xw, r);
}

/// Penalty-generic primal `P(β) = ½‖r‖² + λ·Ω(β)` from a maintained
/// residual. The `P = L1` instantiation is [`primal_from_residual`]
/// expression for expression (the penalty's `value` is
/// `lambda * l1_norm(beta)` verbatim), so the ℓ₁ bits are unchanged.
#[inline]
pub fn penalty_primal_from_residual<P: crate::penalty::Penalty>(
    r: &[f64],
    beta: &[f64],
    lambda: f64,
    penalty: &P,
) -> f64 {
    if P::IS_L1 {
        return primal_from_residual(r, beta, lambda);
    }
    0.5 * crate::util::linalg::dot(r, r) + penalty.value(lambda, beta)
}

/// Support (indices of non-zero coefficients).
pub fn support(beta: &[f64]) -> Vec<usize> {
    beta.iter().enumerate().filter(|(_, &b)| b != 0.0).map(|(j, _)| j).collect()
}

/// Support size.
#[inline]
pub fn support_size(beta: &[f64]) -> usize {
    beta.iter().filter(|&&b| b != 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;

    fn sample() -> (DenseMatrix, Vec<f64>) {
        // X = [[1,0],[0,1],[1,1]], y = [1, 2, 3]
        let x = DenseMatrix::from_row_major(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        (x, vec![1.0, 2.0, 3.0])
    }

    #[test]
    fn primal_at_zero_is_half_ynormsq() {
        let (x, y) = sample();
        let p0 = primal(&x, &y, &[0.0, 0.0], 0.7);
        assert!((p0 - 0.5 * 14.0).abs() < 1e-12);
    }

    #[test]
    fn primal_decomposes() {
        let (x, y) = sample();
        let beta = [1.0, -2.0];
        let mut r = vec![0.0; 3];
        residual(&x, &y, &beta, &mut r);
        // r = y - X beta = [1-1, 2+2, 3-(-1)] = [0, 4, 4]
        assert_eq!(r, vec![0.0, 4.0, 4.0]);
        let p = primal(&x, &y, &beta, 0.5);
        assert!((p - (0.5 * 32.0 + 0.5 * 3.0)).abs() < 1e-12);
        assert!((primal_from_residual(&r, &beta, 0.5) - p).abs() < 1e-12);
    }

    #[test]
    fn support_helpers() {
        let beta = [0.0, 1.0, 0.0, -2.0];
        assert_eq!(support(&beta), vec![1, 3]);
        assert_eq!(support_size(&beta), 2);
        assert_eq!(l1_norm(&beta), 3.0);
    }
}
