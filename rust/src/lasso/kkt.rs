//! KKT (subgradient) optimality diagnostics for the Lasso.
//!
//! At optimum: `x_jᵀr̂ = λ·sign(β̂_j)` when `β̂_j ≠ 0`, and `|x_jᵀr̂| ≤ λ`
//! otherwise. GLMNET-style solvers use KKT *violations* to grow their
//! working set; we also use them as a test-time optimality check.

use crate::data::design::DesignOps;

/// Per-feature KKT violation given the residual `r = y − Xβ`.
///
/// For `β_j ≠ 0`: `|x_jᵀr − λ·sign(β_j)|`;
/// for `β_j = 0`: `max(0, |x_jᵀr| − λ)`.
pub fn violations<D: DesignOps>(x: &D, r: &[f64], beta: &[f64], lambda: f64) -> Vec<f64> {
    let mut out = vec![0.0; x.p()];
    crate::util::par::par_fill_cost(&mut out, x.col_cost_hint(), |j| {
        violation_one(x, r, beta[j], lambda, j)
    });
    out
}

/// Fused KKT scan: fill `out[j]` with every per-feature violation AND
/// return their maximum, in one sharded pass over the design (instead
/// of [`violations`] + [`max_violation`] re-reading all p columns
/// twice). `out` is resized to p; the returned max is 0 when p = 0.
pub fn violations_with_max<D: DesignOps>(
    x: &D,
    r: &[f64],
    beta: &[f64],
    lambda: f64,
    out: &mut Vec<f64>,
) -> f64 {
    out.resize(x.p(), 0.0);
    // Violations are non-negative, so the fused |·|-max IS the max.
    crate::util::par::par_fill_abs_max(out, x.col_cost_hint(), |j| {
        violation_one(x, r, beta[j], lambda, j)
    })
}

/// Single-feature violation.
#[inline]
pub fn violation_one<D: DesignOps>(x: &D, r: &[f64], beta_j: f64, lambda: f64, j: usize) -> f64 {
    let g = x.col_dot(j, r);
    if beta_j != 0.0 {
        (g - lambda * beta_j.signum()).abs()
    } else {
        (g.abs() - lambda).max(0.0)
    }
}

/// Penalty-generic [`violation_one`]: the distance from the gradient
/// `g = x_jᵀr` to `λ·∂Ω_j(β_j)` via
/// [`Penalty::subdiff_distance`](crate::penalty::Penalty::subdiff_distance).
/// The `P = L1` instantiation is [`violation_one`]'s expression tree
/// verbatim. Separable penalties only (group penalties need the whole
/// group's gradient).
#[inline]
pub fn violation_one_penalty<D: DesignOps, P: crate::penalty::Penalty>(
    x: &D,
    r: &[f64],
    beta_j: f64,
    lambda: f64,
    j: usize,
    penalty: &P,
) -> f64 {
    debug_assert!(P::SEPARABLE);
    let g = x.col_dot(j, r);
    penalty.subdiff_distance(j, g, beta_j, lambda)
}

/// Maximum violation over all features (0 at an exact optimum).
pub fn max_violation<D: DesignOps>(x: &D, r: &[f64], beta: &[f64], lambda: f64) -> f64 {
    crate::util::par::par_max_cost(x.p(), x.col_cost_hint(), |j| {
        violation_one(x, r, beta[j], lambda, j)
    })
    .max(0.0)
}

/// Features whose violation exceeds `tol` (GLMNET-style KKT check).
/// Runs the fused scan and early-exits when nothing violates.
pub fn violating_features<D: DesignOps>(
    x: &D,
    r: &[f64],
    beta: &[f64],
    lambda: f64,
    tol: f64,
) -> Vec<usize> {
    let mut v = Vec::new();
    if violations_with_max(x, r, beta, lambda, &mut v) <= tol {
        return Vec::new();
    }
    v.into_iter().enumerate().filter(|&(_, v)| v > tol).map(|(j, _)| j).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;
    use crate::lasso::primal::residual;

    #[test]
    fn zero_beta_violation_is_excess_correlation() {
        // X = I2, y = [3, 0.5], lambda = 1
        let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let y = [3.0, 0.5];
        let beta = [0.0, 0.0];
        let mut r = vec![0.0; 2];
        residual(&x, &y, &beta, &mut r);
        let v = violations(&x, &r, &beta, 1.0);
        assert!((v[0] - 2.0).abs() < 1e-12); // |3| - 1
        assert!((v[1] - 0.0).abs() < 1e-12); // |0.5| < 1
    }

    #[test]
    fn optimum_has_zero_violation() {
        // Orthogonal design: beta_hat = ST(X^T y, lambda) for unit columns.
        let x = DenseMatrix::from_row_major(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let y = [3.0, 0.5];
        let lambda = 1.0;
        let beta = [2.0, 0.0]; // ST(3,1)=2, ST(0.5,1)=0
        let mut r = vec![0.0; 2];
        residual(&x, &y, &beta, &mut r);
        assert!(max_violation(&x, &r, &beta, lambda) < 1e-12);
    }

    #[test]
    fn fused_scan_matches_separate() {
        let x = DenseMatrix::from_row_major(2, 3, &[1.0, 0.0, 2.0, 0.0, 1.0, 0.5]);
        let y = [3.0, 0.2];
        let beta = [0.4, 0.0, -0.1];
        let mut r = vec![0.0; 2];
        residual(&x, &y, &beta, &mut r);
        let lambda = 0.7;
        let mut fused = Vec::new();
        let m = violations_with_max(&x, &r, &beta, lambda, &mut fused);
        assert_eq!(fused, violations(&x, &r, &beta, lambda));
        assert_eq!(m.to_bits(), max_violation(&x, &r, &beta, lambda).to_bits());
    }

    #[test]
    fn violating_features_filters() {
        let x = DenseMatrix::from_row_major(2, 3, &[1.0, 0.0, 2.0, 0.0, 1.0, 0.0]);
        let y = [3.0, 0.2];
        let beta = [0.0, 0.0, 0.0];
        let mut r = vec![0.0; 2];
        residual(&x, &y, &beta, &mut r);
        // correlations: [3, 0.2, 6]; lambda = 1 -> features 0 and 2 violate
        let v = violating_features(&x, &r, &beta, 1.0, 1e-9);
        assert_eq!(v, vec![0, 2]);
    }
}
