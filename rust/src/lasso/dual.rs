//! Lasso dual: feasible set, dual objective, duality gap, λ_max,
//! and the canonical residual-rescaling dual point `θ_res` (Eq. 4).
//!
//! Dual problem (Eq. 2):  max_{θ ∈ Δ_X}  ½‖y‖² − (λ²/2)‖θ − y/λ‖²
//! with Δ_X = {θ : ‖Xᵀθ‖_∞ ≤ 1}.

use crate::data::design::DesignOps;

/// Dual objective `D(θ) = ½‖y‖² − (λ²/2)‖θ − y/λ‖²`.
pub fn dual_objective(y: &[f64], theta: &[f64], lambda: f64) -> f64 {
    dual_objective_cached(y, theta, lambda, crate::util::linalg::dot(y, y))
}

/// [`dual_objective`] with `‖y‖²` supplied by the caller. `y` is constant
/// for the lifetime of a solve, so the solver engines cache `‖y‖²` once
/// (see `DualState::update` / the block engine) instead of paying an
/// O(n) pass at every gap check. Also the shape the Multi-Task dual
/// takes (`y`/`theta` are the vectorized n×q matrices, `‖Y‖_F²` cached).
pub fn dual_objective_cached(y: &[f64], theta: &[f64], lambda: f64, y_norm_sq: f64) -> f64 {
    debug_assert_eq!(y.len(), theta.len());
    let mut dist_sq = 0.0;
    for i in 0..y.len() {
        let d = theta[i] - y[i] / lambda;
        dist_sq += d * d;
    }
    0.5 * y_norm_sq - 0.5 * lambda * lambda * dist_sq
}

/// Duality gap `G(β, θ) = P(β) − D(θ)` from a maintained residual.
pub fn gap_from_residual(
    r: &[f64],
    beta: &[f64],
    theta: &[f64],
    y: &[f64],
    lambda: f64,
) -> f64 {
    crate::lasso::primal::primal_from_residual(r, beta, lambda)
        - dual_objective(y, theta, lambda)
}

/// `λ_max = ‖Xᵀy‖_∞`, the smallest λ for which β̂ = 0.
pub fn lambda_max<D: DesignOps>(x: &D, y: &[f64]) -> f64 {
    x.xt_abs_max(y)
}

/// Rescale a residual-like vector into the dual feasible set (Eq. 4):
/// `θ = r / max(λ, ‖Xᵀr‖_∞)`.
///
/// Returns the rescaled point; always feasible by construction.
/// Allocates two fresh buffers per call — hot paths use
/// [`rescale_to_feasible_into`] on workspace buffers instead.
pub fn rescale_to_feasible<D: DesignOps>(x: &D, r: &[f64], lambda: f64) -> Vec<f64> {
    let mut xtr = vec![0.0; x.p()];
    let mut out = Vec::with_capacity(r.len());
    rescale_to_feasible_into(x, r, lambda, &mut xtr, &mut out);
    out
}

/// Allocation-free [`rescale_to_feasible`]: one fused design sweep
/// (`Xᵀr` lands **unscaled** in `xtr` together with its ∞-norm — see
/// [`DesignOps::xt_vec_abs_max`]) plus an n-sized write of `θ = r/denom`
/// into `out` (capacity reused).
///
/// Returns the denominator `max(λ, ‖Xᵀr‖_∞)`, so callers that cache
/// correlations can derive `Xᵀθ = xtr/denom` without a second design
/// sweep — exactly what the CELER outer loop does with its pricing
/// vector. This is the one Eq. 4 rescale every working-set solver
/// (CELER, Blitz, GLMNET's gap diagnostic) routes through.
pub fn rescale_to_feasible_into<D: DesignOps>(
    x: &D,
    r: &[f64],
    lambda: f64,
    xtr: &mut [f64],
    out: &mut Vec<f64>,
) -> f64 {
    glm_rescale_to_feasible_into(x, r, lambda, &crate::datafit::Quadratic, xtr, out)
}

/// Datafit-generic [`rescale_to_feasible_into`]: the denominator comes
/// from [`Datafit::rescale_denom`](crate::datafit::Datafit::rescale_denom)
/// (default `max(λ, ‖Xᵀr‖_∞)`), so a datafit with extra dual box
/// constraints tightens **every** rescale path — this one (the CELER
/// outer loop) and [`DualState::update_datafit`](crate::solvers::DualState::update_datafit)
/// stay consistent by construction.
pub fn glm_rescale_to_feasible_into<D: DesignOps, F: crate::datafit::Datafit>(
    x: &D,
    r: &[f64],
    lambda: f64,
    datafit: &F,
    xtr: &mut [f64],
    out: &mut Vec<f64>,
) -> f64 {
    let denom = datafit.rescale_denom(lambda, x.xt_vec_abs_max(r, xtr));
    out.clear();
    out.extend(r.iter().map(|&v| v / denom));
    denom
}

/// `λ_max` of a GLM datafit: `‖Xᵀ(−∇F(0))‖_∞` — the smallest λ whose
/// solution is β̂ = 0 (quadratic: [`lambda_max`]; logistic `‖Xᵀy‖_∞/2`;
/// Poisson `‖Xᵀ(y−1)‖_∞`).
pub fn glm_lambda_max<D: DesignOps, F: crate::datafit::Datafit>(
    x: &D,
    y: &[f64],
    datafit: &F,
) -> f64 {
    datafit.lambda_max(x, y)
}

/// Penalty-generic [`rescale_to_feasible_into`] (quadratic datafit):
/// `θ = r / max(λ, Ω^D(Xᵀr))` with the penalty's
/// [`dual_norm`](crate::penalty::Penalty::dual_norm). The `P = L1`
/// instantiation delegates to the historical fused-kernel path, bit for
/// bit. `xtr` holds the **unscaled** correlations on return, like every
/// other rescale in this module.
pub fn penalty_rescale_to_feasible_into<D: DesignOps, P: crate::penalty::Penalty>(
    x: &D,
    r: &[f64],
    lambda: f64,
    penalty: &P,
    xtr: &mut [f64],
    out: &mut Vec<f64>,
) -> f64 {
    if P::IS_L1 {
        return rescale_to_feasible_into(x, r, lambda, xtr, out);
    }
    x.xt_vec(r, xtr);
    let denom = crate::datafit::Datafit::rescale_denom(
        &crate::datafit::Quadratic,
        lambda,
        penalty.dual_norm(lambda, xtr),
    );
    out.clear();
    out.extend(r.iter().map(|&v| v / denom));
    denom
}

/// `λ_max` under a generic penalty: `Ω^D₀(Xᵀy)` — the smallest λ whose
/// solution is β̂ = 0 (plain ℓ₁ recovers [`lambda_max`] exactly).
pub fn penalty_lambda_max<D: DesignOps, P: crate::penalty::Penalty>(
    x: &D,
    y: &[f64],
    penalty: &P,
) -> f64 {
    if P::IS_L1 {
        return lambda_max(x, y);
    }
    let mut xty = vec![0.0; x.p()];
    x.xt_vec(y, &mut xty);
    penalty.lambda_max(&xty)
}

/// Penalty-generic dual objective (quadratic datafit):
/// `D(θ) = ½‖y‖² − (λ²/2)‖θ − y/λ‖² − λ·Σ_j ω*(x_jᵀθ)`, where the
/// conjugate term is nonzero only for penalties whose Ω* is finite
/// (elastic net). `xtheta` must hold the **scaled** correlations `Xᵀθ`.
pub fn penalty_dual_objective_cached<P: crate::penalty::Penalty>(
    y: &[f64],
    theta: &[f64],
    xtheta: &[f64],
    lambda: f64,
    y_norm_sq: f64,
    penalty: &P,
) -> f64 {
    let base = dual_objective_cached(y, theta, lambda, y_norm_sq);
    if P::INDICATOR_DUAL {
        base
    } else {
        base - penalty.conjugate(lambda, xtheta, 1.0)
    }
}


/// Check dual feasibility `‖Xᵀθ‖_∞ ≤ 1 + tol`.
pub fn is_feasible<D: DesignOps>(x: &D, theta: &[f64], tol: f64) -> bool {
    x.xt_abs_max(theta) <= 1.0 + tol
}

/// Pick the dual point maximizing `D(θ)` among candidates (Eq. 13).
/// Returns the index of the best candidate.
pub fn best_dual_point(y: &[f64], lambda: f64, candidates: &[&[f64]]) -> usize {
    glm_best_dual_point(
        &crate::datafit::Quadratic,
        y,
        lambda,
        crate::util::linalg::dot(y, y),
        candidates,
    )
}

/// Datafit-generic [`best_dual_point`] (Eq. 13): evaluate the
/// candidates' dual objectives **in order** and return the index of the
/// strict maximizer — first wins ties; out-of-domain candidates
/// (`D = −∞`) can never win. The one copy of the tie-breaking contract
/// every outer loop (CELER, Multi-Task) relies on; `cache` comes from
/// [`Datafit::conj_cache`](crate::datafit::Datafit::conj_cache), computed
/// once per solve instead of per candidate.
pub fn glm_best_dual_point<F: crate::datafit::Datafit>(
    datafit: &F,
    y: &[f64],
    lambda: f64,
    cache: f64,
    candidates: &[&[f64]],
) -> usize {
    let mut best = 0;
    let mut best_val = f64::NEG_INFINITY;
    for (i, th) in candidates.iter().enumerate() {
        let v = datafit.dual(y, th, lambda, cache);
        if v > best_val {
            best_val = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;

    fn sample() -> (DenseMatrix, Vec<f64>) {
        let x = DenseMatrix::from_row_major(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        (x, vec![1.0, 2.0, 3.0])
    }

    #[test]
    fn lambda_max_zeroes_beta() {
        let (x, y) = sample();
        // X^T y = [1+3, 2+3] = [4, 5] -> lambda_max = 5
        assert_eq!(lambda_max(&x, &y), 5.0);
    }

    #[test]
    fn dual_at_y_over_lambda_is_half_ynormsq() {
        let (_, y) = sample();
        let lambda = 2.0;
        let theta: Vec<f64> = y.iter().map(|v| v / lambda).collect();
        assert!((dual_objective(&y, &theta, lambda) - 0.5 * 14.0).abs() < 1e-12);
    }

    #[test]
    fn rescaled_point_is_feasible() {
        let (x, y) = sample();
        for &lambda in &[0.1, 1.0, 5.0, 50.0] {
            let theta = rescale_to_feasible(&x, &y, lambda);
            assert!(is_feasible(&x, &theta, 1e-12), "lambda={lambda}");
        }
    }

    #[test]
    fn rescale_keeps_direction() {
        let (x, y) = sample();
        let theta = rescale_to_feasible(&x, &y, 1.0);
        // denom = max(1, ||X^T y||_inf) = 5
        for i in 0..3 {
            assert!((theta[i] - y[i] / 5.0).abs() < 1e-12);
        }
    }

    #[test]
    fn rescale_into_matches_allocating_and_returns_denom() {
        use crate::data::design::DesignOps;
        let (x, y) = sample();
        let lambda = 1.5;
        let theta = rescale_to_feasible(&x, &y, lambda);
        let mut xtr = vec![0.0; 2];
        let mut out = Vec::new();
        let denom = rescale_to_feasible_into(&x, &y, lambda, &mut xtr, &mut out);
        assert_eq!(theta, out, "wrapper and _into agree");
        assert_eq!(denom, x.xt_abs_max(&y).max(lambda));
        // xtr holds the UNSCALED correlations
        let mut expect = vec![0.0; 2];
        x.xt_vec(&y, &mut expect);
        assert_eq!(xtr, expect);
        // buffers are reused, not reallocated
        let cap = out.capacity();
        let _ = rescale_to_feasible_into(&x, &y, lambda * 2.0, &mut xtr, &mut out);
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn gap_nonnegative_for_feasible_dual() {
        let (x, y) = sample();
        let lambda = 2.5; // = lambda_max / 2
        let beta = [0.1, 0.2];
        let mut r = vec![0.0; 3];
        crate::lasso::primal::residual(&x, &y, &beta, &mut r);
        let theta = rescale_to_feasible(&x, &r, lambda);
        let g = gap_from_residual(&r, &beta, &theta, &y, lambda);
        assert!(g >= 0.0, "gap={g}");
    }

    #[test]
    fn best_dual_point_picks_max() {
        let (_, y) = sample();
        let lambda = 2.0;
        let bad = vec![0.0; 3];
        let good: Vec<f64> = y.iter().map(|v| v / lambda * 0.9).collect();
        assert_eq!(best_dual_point(&y, lambda, &[&bad, &good]), 1);
        assert_eq!(best_dual_point(&y, lambda, &[&good, &bad]), 0);
    }
}
