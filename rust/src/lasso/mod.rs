//! Lasso problem definition: primal/dual objectives, duality gap, KKT.

pub mod dual;
pub mod kkt;
pub mod primal;

use crate::data::design::{DesignMatrix, DesignOps};

/// A fully-specified Lasso problem instance.
#[derive(Debug, Clone)]
pub struct LassoProblem {
    pub x: DesignMatrix,
    pub y: Vec<f64>,
    pub lambda: f64,
}

impl LassoProblem {
    pub fn new(x: DesignMatrix, y: Vec<f64>, lambda: f64) -> Self {
        assert_eq!(x.n(), y.len(), "X rows must match y length");
        assert!(lambda > 0.0, "lambda must be positive");
        LassoProblem { x, y, lambda }
    }

    /// Problem with λ expressed as a fraction of λ_max.
    pub fn with_lambda_ratio(x: DesignMatrix, y: Vec<f64>, ratio: f64) -> Self {
        let lmax = dual::lambda_max(&x, &y);
        Self::new(x, y, lmax * ratio)
    }

    pub fn n(&self) -> usize {
        self.x.n()
    }

    pub fn p(&self) -> usize {
        self.x.p()
    }

    pub fn lambda_max(&self) -> f64 {
        dual::lambda_max(&self.x, &self.y)
    }

    /// Primal objective at β.
    pub fn primal(&self, beta: &[f64]) -> f64 {
        primal::primal(&self.x, &self.y, beta, self.lambda)
    }

    /// Dual objective at θ.
    pub fn dual(&self, theta: &[f64]) -> f64 {
        dual::dual_objective(&self.y, theta, self.lambda)
    }

    /// Duality gap at (β, θ).
    pub fn gap(&self, beta: &[f64], theta: &[f64]) -> f64 {
        self.primal(beta) - self.dual(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseMatrix;

    fn problem() -> LassoProblem {
        let x = DesignMatrix::Dense(DenseMatrix::from_row_major(
            3,
            2,
            &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
        ));
        LassoProblem::new(x, vec![1.0, 2.0, 3.0], 1.0)
    }

    #[test]
    fn accessors() {
        let pb = problem();
        assert_eq!(pb.n(), 3);
        assert_eq!(pb.p(), 2);
        assert_eq!(pb.lambda_max(), 5.0);
    }

    #[test]
    fn ratio_constructor() {
        let pb = problem();
        let pb2 = LassoProblem::with_lambda_ratio(pb.x.clone(), pb.y.clone(), 0.2);
        assert!((pb2.lambda - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_lambda() {
        let pb = problem();
        let _ = LassoProblem::new(pb.x, pb.y, 0.0);
    }

    #[test]
    fn gap_is_primal_minus_dual() {
        let pb = problem();
        let beta = [0.5, 0.5];
        let theta = crate::lasso::dual::rescale_to_feasible(&pb.x, &pb.y, pb.lambda);
        let g = pb.gap(&beta, &theta);
        assert!((g - (pb.primal(&beta) - pb.dual(&theta))).abs() < 1e-12);
    }
}
