//! The penalty layer: separable (and group-separable) sparsity-enforcing
//! penalties behind one trait, mirroring the [`crate::datafit`] layer.
//!
//! Gap Safe screening rules are stated for generic sparsity-enforcing
//! penalties (Ndiaye et al. 2017, PAPERS.md), and the CELER working-set
//! construction (Algorithm 2/4 of the source paper) only needs three
//! penalty-specific quantities: a prox (for the CD epoch), a dual norm
//! (the Eq. 4 rescale denominator), and a subdifferential distance (the
//! d-score pricing of Eqs. 10–11). [`Penalty`] packages exactly that
//! surface, and the engine ([`crate::solvers::engine`]), the CELER outer
//! loop ([`crate::solvers::celer`]) and the batched multi-λ lanes
//! ([`crate::solvers::batch`]) take it generically.
//!
//! **Bit-identity invariant.** The [`L1`] instantiation is the
//! pre-refactor engine, expression for expression: every generic
//! consumer branches on [`Penalty::IS_L1`] and takes the exact
//! historical fused path (`soft_threshold(old + g / nrm, lambda / nrm)`,
//! `xt_vec_abs_max` rescales, `(1 − |x_jᵀθ|)/‖x_j‖` d-scores), so the
//! existing bitwise pins (quadratic-datafit legacy, q = 1 block,
//! pooled == serial) stay green. `tests/prop_penalty.rs` pins the
//! `Penalty = L1` engine and CELER solves against a test-local port of
//! the pre-refactor ℓ₁ code.
//!
//! **Dual conventions.** Every solve normalizes the dual point as
//! θ = r / denom with `denom = max(λ, Ω^D(Xᵀr))` (Eq. 4 generalized),
//! where `Ω^D` is [`Penalty::dual_norm`]:
//!
//! | penalty       | Ω(β)                             | Ω^D(u) (slab)          |
//! |---------------|----------------------------------|------------------------|
//! | [`L1`]        | ‖β‖₁                             | ‖u‖_∞                  |
//! | [`WeightedL1`]| Σ w_j·\|β_j\|                    | max_{w_j>0} \|u_j\|/w_j|
//! | [`GroupLasso`]| Σ_g ‖β_g‖₂                       | max_g ‖u_g‖₂           |
//! | [`ElasticNet`]| α‖β‖₁ + ½(1−α)‖β‖₂²              | — (no constraint)      |
//!
//! The elastic net's conjugate is finite everywhere (the penalty is
//! strongly convex), so its dual point needs **no** rescale
//! (`dual_norm` returns 0, the denominator collapses to λ) and the dual
//! objective instead subtracts the explicit conjugate term
//! [`Penalty::conjugate`]: D(θ) = −F*(−λθ) − λ·Σ_j ω*(x_jᵀθ) with
//! ω*(v) = (|v| − α)₊² / (2(1−α)). Features still screen with the plain
//! √(2·gap)/λ Gap Safe ball — the extra concave dual term only sharpens
//! the bound — against the slab |x_jᵀθ̂| ≤ α (β̂_j = 0 ⇔ the ℓ₁ part of
//! the subdifferential absorbs the correlation).
//!
//! **Unpenalized features.** [`WeightedL1`] treats `w_j = 0` as
//! unpenalized (never screened, always kept in working sets — the
//! intercept convention) and `w_j = ∞` as hard-zeroed. Zero-weight
//! coordinates are skipped by the dual rescale: their correlations
//! vanish at optimum, so the reported gap is exact in the limit and a
//! certified upper bound once the unpenalized coordinates are solved —
//! the same convention production lasso libraries use for intercepts.

use crate::data::design::DesignOps;
use crate::util::soft_threshold;

/// Distance margin reused by the generic Gap-Safe keep test — the same
/// constant [`crate::screening::ScreeningState::screen`] adds to the
/// radius before comparing d-scores.
pub const SCREEN_MARGIN: f64 = 1e-12;

/// A separable (or contiguous-group-separable) sparsity-enforcing
/// penalty `λ·Ω(β)`: the quantities the engine, CELER outer loop, Gap
/// Safe screening and λ-path anchoring need, and nothing else.
///
/// Methods take the *current* regularization level `lambda` explicitly —
/// one penalty value serves a whole warm-started λ path, exactly like a
/// [`Datafit`](crate::datafit::Datafit).
pub trait Penalty: Sync {
    /// Marker for the plain ℓ₁ penalty: generic consumers branch on this
    /// to take the exact historical fused expressions (the bit-identity
    /// invariant — see the module docs).
    const IS_L1: bool = false;

    /// Coordinate-separable: the scalar [`Penalty::prox`] is exact and
    /// scalar cyclic CD applies. `false` for [`GroupLasso`], whose prox
    /// couples coordinates within a group (the engine then updates one
    /// contiguous group per visit — see
    /// [`CdStrategy`](crate::solvers::engine::CdStrategy)).
    const SEPARABLE: bool = true;

    /// The convex conjugate Ω* is an indicator (dual feasibility is a
    /// slab enforced by the Eq. 4 rescale, [`Penalty::conjugate`] is
    /// zero). `false` for [`ElasticNet`], whose finite conjugate is
    /// subtracted from the dual objective instead.
    const INDICATOR_DUAL: bool = true;

    /// Scalar prox for coordinate `j`: the minimizer of
    /// `½·nrm·(b − u)² + λ·Ω_j(b)`. The [`L1`] impl is exactly the
    /// historical CD update `soft_threshold(u, lambda / nrm)`.
    ///
    /// Only meaningful when [`Penalty::SEPARABLE`]; group penalties
    /// panic here and expose their block prox via [`Penalty::prox_vec`].
    fn prox(&self, j: usize, u: f64, lambda: f64, nrm: f64) -> f64;

    /// Full-vector prox with uniform curvature `nrm`: the minimizer of
    /// `½·nrm·‖b − u‖² + λ·Ω(b)` written into `out`. Defaults to the
    /// scalar prox per coordinate; [`GroupLasso`] overrides with the
    /// block soft-threshold per group. This is the single prox surface
    /// the conformance suite exercises for every impl.
    fn prox_vec(&self, u: &[f64], lambda: f64, nrm: f64, out: &mut [f64]) {
        assert_eq!(u.len(), out.len());
        for (j, (&v, o)) in u.iter().zip(out.iter_mut()).enumerate() {
            *o = self.prox(j, v, lambda, nrm);
        }
    }

    /// `λ·Ω(β)` — the penalty term of the primal objective. The [`L1`]
    /// impl is exactly the historical `lambda * l1_norm(beta)`.
    fn value(&self, lambda: f64, beta: &[f64]) -> f64;

    /// Generalized dual norm `Ω^D(u)` of a correlation vector `u = Xᵀr`:
    /// the Eq. 4 rescale denominator is
    /// `rescale_denom(λ, Ω^D(Xᵀr)) = max(λ, Ω^D(Xᵀr))`, making
    /// `θ = r/denom` dual-feasible. Penalties without a dual constraint
    /// ([`ElasticNet`]) return 0, collapsing the denominator to λ.
    fn dual_norm(&self, lambda: f64, u: &[f64]) -> f64;

    /// The finite conjugate term `λ·Σ_j ω*_j(u_j·scale)` subtracted from
    /// the dual objective when [`Penalty::INDICATOR_DUAL`] is false
    /// (`u·scale = Xᵀθ`). Zero for slab penalties.
    fn conjugate(&self, _lambda: f64, _u: &[f64], _scale: f64) -> f64 {
        0.0
    }

    /// Distance from the gradient `g = x_jᵀr` to the subdifferential
    /// `λ·∂Ω_j(β_j)` — the KKT violation of coordinate `j`
    /// (generalizes [`crate::lasso::kkt::violation_one`], which is the
    /// exact [`L1`] expression). Only meaningful for separable
    /// penalties; [`GroupLasso`] panics (group KKT residuals need the
    /// whole group's gradient).
    fn subdiff_distance(&self, j: usize, g: f64, beta_j: f64, lambda: f64) -> f64;

    /// Per-feature d-score (Eq. 10 generalized): the normalized distance
    /// from the cached dual correlations `xtheta = Xᵀθ` to feature `j`'s
    /// dual-feasibility slab, in units of `‖x_j‖`. Smaller = higher
    /// working-set priority; the Gap Safe keep test is
    /// `d_score ≤ radius + SCREEN_MARGIN`. Conventions: `+∞` excludes a
    /// feature from working sets and screens it on the next pass (empty
    /// columns, `w_j = ∞`); any negative constant keeps it
    /// unconditionally and prices it first (`w_j = 0`).
    fn d_score(&self, j: usize, lambda: f64, xtheta: &[f64], col_norms: &[f64]) -> f64;

    /// Gap Safe radius of the dual uncertainty ball for the quadratic
    /// datafit: `√(2·gap)/λ` for every penalty here (the radius comes
    /// from the datafit's strong dual concavity; extra concave penalty
    /// terms only shrink the true ball, so the bound stays safe).
    fn gap_safe_radius(&self, gap: f64, lambda: f64) -> f64 {
        (2.0 * gap.max(0.0)).sqrt() / lambda
    }

    /// Smallest λ at which `β = 0` is optimal, from the zero-iterate
    /// correlations `u = Xᵀ(−∇F(0))` (= `Xᵀy` for the quadratic
    /// datafit): `λ_max = Ω^D₀(u)` where Ω^D₀ is the dual norm of the
    /// *sparsity-enforcing part* of the penalty (the ℓ₁ part for the
    /// elastic net).
    fn lambda_max(&self, u: &[f64]) -> f64;

    /// Restriction of the penalty to the feature subset `idx` (in order):
    /// the penalty the working-set inner solves see, where coordinate `t`
    /// of the subproblem is global feature `idx[t]`. Index-independent
    /// penalties return themselves; [`WeightedL1`] gathers its weights.
    /// Required because CELER's zero-copy `DesignView` subproblems call
    /// [`Penalty::prox`] / [`Penalty::subdiff_distance`] with **local**
    /// column indices.
    fn restrict(&self, idx: &[usize]) -> Self
    where
        Self: Sized;

    /// Contiguous group width (1 for separable penalties). The last
    /// group may be ragged when `p % group_size != 0`.
    fn group_size(&self) -> usize {
        1
    }

    /// Whether feature `j` is actually penalized (`false` only for
    /// [`WeightedL1`] features with `w_j = 0`). Unpenalized features are
    /// exempt from screening and λ_max anchoring.
    fn is_penalized(&self, j: usize) -> bool {
        let _ = j;
        true
    }
}

/// Plain ℓ₁: `Ω(β) = ‖β‖₁`. The pre-refactor engine, bit for bit — see
/// the module docs for the invariant and `tests/prop_penalty.rs` for the
/// pin.
#[derive(Debug, Clone, Copy, Default)]
pub struct L1;

impl Penalty for L1 {
    const IS_L1: bool = true;

    #[inline]
    fn prox(&self, _j: usize, u: f64, lambda: f64, nrm: f64) -> f64 {
        soft_threshold(u, lambda / nrm)
    }

    fn value(&self, lambda: f64, beta: &[f64]) -> f64 {
        lambda * crate::lasso::primal::l1_norm(beta)
    }

    fn dual_norm(&self, _lambda: f64, u: &[f64]) -> f64 {
        u.iter().fold(0.0f64, |a, &b| a.max(b.abs()))
    }

    #[inline]
    fn subdiff_distance(&self, _j: usize, g: f64, beta_j: f64, lambda: f64) -> f64 {
        if beta_j != 0.0 {
            (g - lambda * beta_j.signum()).abs()
        } else {
            (g.abs() - lambda).max(0.0)
        }
    }

    #[inline]
    fn d_score(&self, j: usize, _lambda: f64, xtheta: &[f64], col_norms: &[f64]) -> f64 {
        crate::screening::d_score(xtheta[j].abs(), col_norms[j])
    }

    fn lambda_max(&self, u: &[f64]) -> f64 {
        u.iter().fold(0.0f64, |a, &b| a.max(b.abs()))
    }

    fn restrict(&self, _idx: &[usize]) -> Self {
        L1
    }
}

/// Elastic net: `Ω(β) = α‖β‖₁ + ½(1−α)‖β‖₂²` with `α ∈ (0, 1)` (the
/// sklearn `l1_ratio` convention — both terms scale with λ along a
/// path). Strongly convex, so the dual is unconstrained: `dual_norm`
/// is 0 and the finite conjugate is subtracted via
/// [`Penalty::conjugate`]. EN(λ, α) on `X` is the Lasso at `λα` on the
/// augmented design `[X; √(λ(1−α))·I]` — `tests/prop_penalty.rs`
/// cross-checks solves against exactly that reduction.
#[derive(Debug, Clone, Copy)]
pub struct ElasticNet {
    /// ℓ₁ fraction α ∈ (0, 1). α → 1 is the plain Lasso (use [`L1`]),
    /// α → 0 is ridge (no sparsity, unsupported here).
    pub l1_ratio: f64,
}

impl ElasticNet {
    pub fn new(l1_ratio: f64) -> Self {
        assert!(
            l1_ratio > 0.0 && l1_ratio < 1.0,
            "elastic net needs 0 < l1_ratio < 1 (use the L1 penalty at l1_ratio = 1), got {l1_ratio}"
        );
        ElasticNet { l1_ratio }
    }
}

impl Penalty for ElasticNet {
    const INDICATOR_DUAL: bool = false;

    #[inline]
    fn prox(&self, _j: usize, u: f64, lambda: f64, nrm: f64) -> f64 {
        // argmin ½·nrm·(b−u)² + λα|b| + ½λ(1−α)b²
        soft_threshold(u, lambda * self.l1_ratio / nrm)
            / (1.0 + lambda * (1.0 - self.l1_ratio) / nrm)
    }

    fn value(&self, lambda: f64, beta: &[f64]) -> f64 {
        lambda
            * (self.l1_ratio * crate::lasso::primal::l1_norm(beta)
                + 0.5 * (1.0 - self.l1_ratio) * crate::util::linalg::dot(beta, beta))
    }

    fn dual_norm(&self, _lambda: f64, _u: &[f64]) -> f64 {
        // No dual constraint: the rescale denominator collapses to λ.
        0.0
    }

    fn conjugate(&self, lambda: f64, u: &[f64], scale: f64) -> f64 {
        // λ·Σ ω*(u_j·scale), ω*(v) = (|v| − α)₊² / (2(1−α))
        let a = self.l1_ratio;
        let mut acc = 0.0;
        for &v in u {
            let excess = (v * scale).abs() - a;
            if excess > 0.0 {
                acc += excess * excess;
            }
        }
        lambda * acc / (2.0 * (1.0 - a))
    }

    #[inline]
    fn subdiff_distance(&self, _j: usize, g: f64, beta_j: f64, lambda: f64) -> f64 {
        let a = self.l1_ratio;
        if beta_j != 0.0 {
            (g - lambda * (1.0 - a) * beta_j - lambda * a * beta_j.signum()).abs()
        } else {
            (g.abs() - lambda * a).max(0.0)
        }
    }

    #[inline]
    fn d_score(&self, j: usize, _lambda: f64, xtheta: &[f64], col_norms: &[f64]) -> f64 {
        let norm = col_norms[j];
        if norm == 0.0 {
            return f64::INFINITY;
        }
        // β̂_j = 0 ⇔ |x_jᵀθ̂| ≤ α: the slab half-width is α, not 1.
        (self.l1_ratio - xtheta[j].abs()) / norm
    }

    fn lambda_max(&self, u: &[f64]) -> f64 {
        // β = 0 optimal ⇔ |x_jᵀy| ≤ λα for all j.
        u.iter().fold(0.0f64, |a, &b| a.max(b.abs())) / self.l1_ratio
    }

    fn restrict(&self, _idx: &[usize]) -> Self {
        *self
    }
}

/// Weighted ℓ₁: `Ω(β) = Σ_j w_j·|β_j|` with per-feature weights
/// `w_j ≥ 0`. `w_j = 0` marks an unpenalized feature (never screened,
/// always in working sets); `w_j = ∞` hard-zeroes a feature (screened
/// immediately, prox pins it to 0). Everything in between is the
/// adaptive-lasso workhorse.
#[derive(Debug, Clone)]
pub struct WeightedL1 {
    pub weights: Vec<f64>,
}

impl WeightedL1 {
    pub fn new(weights: Vec<f64>) -> Self {
        assert!(
            weights.iter().all(|&w| w >= 0.0 && !w.is_nan()),
            "weighted-ℓ₁ weights must be non-negative"
        );
        WeightedL1 { weights }
    }
}

impl Penalty for WeightedL1 {
    #[inline]
    fn prox(&self, j: usize, u: f64, lambda: f64, nrm: f64) -> f64 {
        let w = self.weights[j];
        if w == 0.0 {
            u
        } else if w.is_infinite() {
            0.0
        } else {
            soft_threshold(u, lambda * w / nrm)
        }
    }

    fn value(&self, lambda: f64, beta: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                // w = ∞ with β ≠ 0 correctly yields an infinite objective.
                acc += self.weights[j] * b.abs();
            }
        }
        lambda * acc
    }

    fn dual_norm(&self, _lambda: f64, u: &[f64]) -> f64 {
        let mut m = 0.0f64;
        for (j, &v) in u.iter().enumerate() {
            let w = self.weights[j];
            if w > 0.0 {
                // |v|/∞ = 0: hard-zeroed features never constrain θ.
                m = m.max(v.abs() / w);
            }
        }
        m
    }

    #[inline]
    fn subdiff_distance(&self, j: usize, g: f64, beta_j: f64, lambda: f64) -> f64 {
        let w = self.weights[j];
        if w == 0.0 {
            g.abs()
        } else if w.is_infinite() {
            0.0
        } else if beta_j != 0.0 {
            (g - lambda * w * beta_j.signum()).abs()
        } else {
            (g.abs() - lambda * w).max(0.0)
        }
    }

    #[inline]
    fn d_score(&self, j: usize, _lambda: f64, xtheta: &[f64], col_norms: &[f64]) -> f64 {
        let w = self.weights[j];
        let norm = col_norms[j];
        if norm == 0.0 || w.is_infinite() {
            return f64::INFINITY;
        }
        if w == 0.0 {
            // Unpenalized: priced first, kept by every screen pass.
            return -1.0;
        }
        (w - xtheta[j].abs()) / norm
    }

    fn lambda_max(&self, u: &[f64]) -> f64 {
        let mut m = 0.0f64;
        for (j, &v) in u.iter().enumerate() {
            let w = self.weights[j];
            if w > 0.0 {
                m = m.max(v.abs() / w);
            }
        }
        m
    }

    fn is_penalized(&self, j: usize) -> bool {
        self.weights[j] > 0.0
    }

    fn restrict(&self, idx: &[usize]) -> Self {
        WeightedL1 { weights: idx.iter().map(|&j| self.weights[j]).collect() }
    }
}

/// Group-ℓ₂ over contiguous blocks of `grp_size` features:
/// `Ω(β) = Σ_g ‖β_g‖₂` (unit group weights; the last group may be
/// ragged). Not coordinate-separable — the engine updates one group per
/// column visit with the block soft-threshold and the Frobenius
/// majorizer `L_g = Σ_{j∈g} ‖x_j‖² ≥ ‖X_g‖₂²`, and screening/pricing
/// use group-level scores shared by every member feature.
#[derive(Debug, Clone, Copy)]
pub struct GroupLasso {
    pub grp_size: usize,
}

impl GroupLasso {
    pub fn new(grp_size: usize) -> Self {
        assert!(grp_size >= 1, "group size must be >= 1");
        GroupLasso { grp_size }
    }

    /// `[start, end)` column range of feature `j`'s group in a width-`p`
    /// problem.
    #[inline]
    pub fn group_range(&self, j: usize, p: usize) -> (usize, usize) {
        let start = (j / self.grp_size) * self.grp_size;
        (start, (start + self.grp_size).min(p))
    }
}

impl Penalty for GroupLasso {
    const SEPARABLE: bool = false;

    fn prox(&self, _j: usize, _u: f64, _lambda: f64, _nrm: f64) -> f64 {
        unreachable!("the group-ℓ₂ prox is not coordinate-separable; use prox_vec")
    }

    fn prox_vec(&self, u: &[f64], lambda: f64, nrm: f64, out: &mut [f64]) {
        assert_eq!(u.len(), out.len());
        out.copy_from_slice(u);
        for chunk in out.chunks_mut(self.grp_size) {
            crate::multitask::block_soft_threshold(chunk, lambda / nrm);
        }
    }

    fn value(&self, lambda: f64, beta: &[f64]) -> f64 {
        let mut acc = 0.0;
        for chunk in beta.chunks(self.grp_size) {
            acc += crate::util::linalg::norm(chunk);
        }
        lambda * acc
    }

    fn dual_norm(&self, _lambda: f64, u: &[f64]) -> f64 {
        let mut m = 0.0f64;
        for chunk in u.chunks(self.grp_size) {
            m = m.max(crate::util::linalg::norm(chunk));
        }
        m
    }

    fn subdiff_distance(&self, _j: usize, _g: f64, _beta_j: f64, _lambda: f64) -> f64 {
        unreachable!("group-ℓ₂ KKT residuals need the whole group's gradient")
    }

    fn d_score(&self, j: usize, _lambda: f64, xtheta: &[f64], col_norms: &[f64]) -> f64 {
        let (start, end) = self.group_range(j, col_norms.len());
        let mut corr_sq = 0.0;
        let mut fro_sq = 0.0;
        for k in start..end {
            corr_sq += xtheta[k] * xtheta[k];
            fro_sq += col_norms[k] * col_norms[k];
        }
        if fro_sq == 0.0 {
            return f64::INFINITY;
        }
        // Group slab ‖X_gᵀθ‖₂ ≤ 1, uncertainty radius·‖X_g‖_F.
        (1.0 - corr_sq.sqrt()) / fro_sq.sqrt()
    }

    fn lambda_max(&self, u: &[f64]) -> f64 {
        self.dual_norm(f64::NAN, u)
    }

    fn restrict(&self, _idx: &[usize]) -> Self {
        unreachable!("group-ℓ₂ runs through the plain engine, not working-set restrictions")
    }

    fn group_size(&self) -> usize {
        self.grp_size
    }
}

/// Scale-adaptive weights for [`WeightedL1`]: `w_j = ‖x_j‖ / max_k ‖x_k‖`
/// — penalizing features proportionally to their column scale, i.e. the
/// standardized Lasso without touching the design. Empty columns get
/// `w = ∞` (they can never enter the model anyway). This is what the
/// `"celer-wlasso"` path solver uses.
pub fn scale_weights<D: DesignOps>(x: &D) -> Vec<f64> {
    let p = x.p();
    let mut norms = vec![0.0; p];
    for (j, w) in norms.iter_mut().enumerate() {
        *w = x.col_norm_sq(j).sqrt();
    }
    let max = norms.iter().fold(0.0f64, |a, &b| a.max(b));
    if max == 0.0 {
        return vec![f64::INFINITY; p];
    }
    for w in norms.iter_mut() {
        *w = if *w == 0.0 { f64::INFINITY } else { *w / max };
    }
    norms
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prox_objective<P: Penalty>(pen: &P, lambda: f64, nrm: f64, u: &[f64], b: &[f64]) -> f64 {
        let mut quad = 0.0;
        for (x, y) in b.iter().zip(u.iter()) {
            quad += (x - y) * (x - y);
        }
        0.5 * nrm * quad + pen.value(lambda, b)
    }

    #[test]
    fn l1_prox_is_soft_threshold_bits() {
        let pen = L1;
        for (u, lambda, nrm) in [(1.5, 0.3, 1.0), (-0.2, 0.5, 2.0), (0.7, 0.7, 0.9)] {
            assert_eq!(
                pen.prox(0, u, lambda, nrm).to_bits(),
                soft_threshold(u, lambda / nrm).to_bits()
            );
        }
    }

    #[test]
    fn elastic_net_prox_closed_form() {
        let pen = ElasticNet::new(0.6);
        let (lambda, nrm) = (0.8, 1.7);
        for u in [-2.0, -0.3, 0.0, 0.4, 3.0] {
            let b = pen.prox(0, u, lambda, nrm);
            // beats nearby candidates on the prox objective
            let f0 = prox_objective(&pen, lambda, nrm, &[u], &[b]);
            for d in [-1e-4, 1e-4, -0.05, 0.05] {
                let f1 = prox_objective(&pen, lambda, nrm, &[u], &[b + d]);
                assert!(f0 <= f1 + 1e-12, "u={u} d={d}: {f0} > {f1}");
            }
        }
    }

    #[test]
    fn weighted_prox_zero_and_infinite_weights() {
        let pen = WeightedL1::new(vec![0.0, 1.0, f64::INFINITY]);
        assert_eq!(pen.prox(0, 2.5, 0.7, 1.3), 2.5); // unpenalized: identity
        assert_eq!(pen.prox(2, 2.5, 0.7, 1.3), 0.0); // hard-zeroed
        assert_eq!(
            pen.prox(1, 2.5, 0.7, 1.3).to_bits(),
            soft_threshold(2.5, 0.7 * 1.0 / 1.3).to_bits()
        );
    }

    #[test]
    fn group_prox_is_block_soft_threshold() {
        let pen = GroupLasso::new(2);
        let u = [3.0, 4.0, 0.1, -0.1, 2.0]; // ragged last group
        let mut out = [0.0; 5];
        pen.prox_vec(&u, 1.0, 1.0, &mut out);
        // group 0: norm 5, shrink by (1 − 1/5)
        assert!((out[0] - 3.0 * 0.8).abs() < 1e-12);
        assert!((out[1] - 4.0 * 0.8).abs() < 1e-12);
        // group 1: norm ≈ 0.141 < 1 ⇒ zeroed
        assert_eq!(out[2], 0.0);
        assert_eq!(out[3], 0.0);
        // ragged group 2: norm 2, shrink by ½
        assert!((out[4] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dual_norms_and_lambda_max() {
        let u = [3.0, -4.0, 1.0, 0.5];
        assert_eq!(L1.dual_norm(1.0, &u), 4.0);
        assert_eq!(L1.lambda_max(&u), 4.0);
        let en = ElasticNet::new(0.5);
        assert_eq!(en.dual_norm(1.0, &u), 0.0);
        assert_eq!(en.lambda_max(&u), 8.0);
        let wl = WeightedL1::new(vec![0.0, 2.0, 1.0, f64::INFINITY]);
        assert_eq!(wl.dual_norm(1.0, &u), 2.0); // max(4/2, 1/1), skips w=0 and w=∞
        let gl = GroupLasso::new(2);
        assert_eq!(gl.dual_norm(1.0, &u), 5.0); // ‖(3,−4)‖ = 5 > ‖(1,0.5)‖
    }

    #[test]
    fn elastic_net_conjugate_fenchel_young() {
        // Ω(β) + Ω*(u) ≥ uᵀβ, with equality at u ∈ ∂Ω(β).
        let pen = ElasticNet::new(0.4);
        let lambda = 1.0;
        let beta = [1.5, -0.2, 0.0, 0.8];
        let a = 0.4;
        // u_j = α·sign(β_j) + (1−α)β_j ∈ ∂Ω(β_j)
        let u: Vec<f64> =
            beta.iter().map(|&b| if b == 0.0 { 0.0 } else { a * b.signum() + (1.0 - a) * b }).collect();
        let lhs = pen.value(lambda, &beta) + pen.conjugate(lambda, &u, 1.0);
        let dot: f64 = u.iter().zip(beta.iter()).map(|(x, y)| x * y).sum();
        assert!((lhs - lambda * dot).abs() < 1e-12, "{lhs} vs {}", lambda * dot);
        // and a generic point satisfies the inequality
        let v = [0.9, 0.9, 0.9, 0.9];
        let lhs = pen.value(lambda, &beta) + pen.conjugate(lambda, &v, 1.0);
        let dot: f64 = v.iter().zip(beta.iter()).map(|(x, y)| x * y).sum();
        assert!(lhs >= lambda * dot - 1e-12);
    }

    #[test]
    fn subdiff_distance_matches_kkt_shapes() {
        // L1 matches the historical violation_one expression.
        let (g, lambda) = (0.7, 0.5);
        assert_eq!(L1.subdiff_distance(0, g, 0.0, lambda), (g.abs() - lambda).max(0.0));
        assert_eq!(L1.subdiff_distance(0, g, 2.0, lambda), (g - lambda).abs());
        // EN at an exact stationary coordinate has zero violation.
        let en = ElasticNet::new(0.6);
        let b = -1.2;
        let g_star = lambda * (1.0 - 0.6) * b + lambda * 0.6 * b.signum();
        assert!(en.subdiff_distance(0, g_star, b, lambda).abs() < 1e-15);
    }

    #[test]
    fn d_score_conventions() {
        let xtheta = [0.3, 0.9, 0.1, 0.2];
        let norms = [1.0, 1.0, 0.0, 1.0];
        // L1 matches the screening helper exactly.
        assert_eq!(
            L1.d_score(0, 1.0, &xtheta, &norms).to_bits(),
            crate::screening::d_score(0.3, 1.0).to_bits()
        );
        assert_eq!(L1.d_score(2, 1.0, &xtheta, &norms), f64::INFINITY);
        let wl = WeightedL1::new(vec![0.0, 1.0, 1.0, f64::INFINITY]);
        assert_eq!(wl.d_score(0, 1.0, &xtheta, &norms), -1.0);
        assert_eq!(wl.d_score(3, 1.0, &xtheta, &norms), f64::INFINITY);
        // Group scores are shared across the group's features.
        let gl = GroupLasso::new(2);
        assert_eq!(
            gl.d_score(0, 1.0, &xtheta, &norms).to_bits(),
            gl.d_score(1, 1.0, &xtheta, &norms).to_bits()
        );
    }

    #[test]
    fn scale_weights_standardize() {
        use crate::data::dense::DenseMatrix;
        // col norms: 1, 2, 0
        let x = DenseMatrix::from_col_major(2, 3, vec![1.0, 0.0, 0.0, 2.0, 0.0, 0.0]);
        let w = scale_weights(&x);
        assert!((w[0] - 0.5).abs() < 1e-15);
        assert!((w[1] - 1.0).abs() < 1e-15);
        assert!(w[2].is_infinite());
    }
}
