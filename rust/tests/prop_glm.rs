//! Property tests for the datafit abstraction (sparse GLM engine).
//!
//! 1. Finite-difference checks: each datafit's generalized residual is
//!    the negative gradient of its value, and its IRLS weights are the
//!    second derivative.
//! 2. **Bit-identity pin**: the quadratic datafit through the generic
//!    engine (`cd_solve` → `engine::solve_datafit` with `Quadratic`) is
//!    bitwise equal to a faithful test-local port of the PRE-refactor
//!    engine loop (CD epochs, hardcoded dual update, hardcoded Gap Safe
//!    screening) — dense + CSC, screening on/off, extrapolation on/off.
//! 3. Logistic CELER solves terminate with a duality gap ≤ tol certified
//!    by the extrapolated dual point, and match an unscreened full-design
//!    prox-Newton reference on the objective.
//! 4. GLM λ-path workspace reuse is bit-invariant.

use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::synth;
use celer::datafit::{Datafit, GlmFamily, Logistic, Poisson, Quadratic};
use celer::extrapolation::ResidualBuffer;
use celer::lasso::{dual, primal};
use celer::solvers::cd::{cd_solve, CdConfig};
use celer::solvers::engine::Workspace;
use celer::solvers::glm::{glm_cd_solve, logreg_lambda_max, sparse_logreg_solve};
use celer::solvers::path::{glm_path_with_workspace, lambda_grid};
use celer::solvers::DualScratch;

// ---------------------------------------------------------------------
// 1. finite-difference gradient / curvature checks
// ---------------------------------------------------------------------

fn fd_check<F: Datafit>(datafit: &F, y: &[f64], xw: &[f64], tol: f64) {
    let n = y.len();
    let mut r = vec![0.0; n];
    datafit.fill_residual(y, xw, &mut r);
    let mut w = vec![0.0; n];
    datafit.fill_weights(y, xw, &mut w);
    let eps = 1e-6;
    let (mut up, mut dn) = (xw.to_vec(), xw.to_vec());
    let (mut ru, mut rd) = (vec![0.0; n], vec![0.0; n]);
    for i in 0..n {
        up[i] = xw[i] + eps;
        dn[i] = xw[i] - eps;
        datafit.fill_residual(y, &up, &mut ru);
        datafit.fill_residual(y, &dn, &mut rd);
        // gradient: dF/du_i = −r_i
        let g = (datafit.value(y, &up, &ru) - datafit.value(y, &dn, &rd)) / (2.0 * eps);
        assert!(
            (g + r[i]).abs() < tol,
            "{}: dF/du[{i}] = {g}, −r = {}",
            datafit.name(),
            -r[i]
        );
        // curvature: d²F/du_i² = w_i = −dr_i/du_i
        let h = -(ru[i] - rd[i]) / (2.0 * eps);
        assert!(
            (h - w[i]).abs() < tol,
            "{}: d²F/du[{i}]² = {h}, w = {}",
            datafit.name(),
            w[i]
        );
        up[i] = xw[i];
        dn[i] = xw[i];
    }
}

#[test]
fn datafit_derivatives_match_finite_differences() {
    let mut rng = celer::util::rng::Rng::new(123);
    let n = 40;
    let xw: Vec<f64> = (0..n).map(|_| rng.normal() * 0.8).collect();
    let y_reg: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let y_cls: Vec<f64> = (0..n).map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 }).collect();
    let y_cnt: Vec<f64> = (0..n).map(|_| (rng.uniform() * 4.0).floor()).collect();
    fd_check(&Quadratic, &y_reg, &xw, 1e-5);
    fd_check(&Logistic, &y_cls, &xw, 1e-5);
    fd_check(&Poisson, &y_cnt, &xw, 1e-4);
}

// ---------------------------------------------------------------------
// 2. quadratic bit-identity vs the pre-refactor engine
// ---------------------------------------------------------------------

/// Faithful port of the pre-datafit engine state: the hardcoded
/// quadratic dual update (Eq. 4 rescale + fused D(θ_res) + θ_accel +
/// Eq. 13 monotone best) exactly as `DualState::update` inlined it
/// before the refactor.
struct LegacyDual {
    buffer: ResidualBuffer,
    theta: Vec<f64>,
    xtheta: Vec<f64>,
    dval: f64,
    y_norm_sq: f64,
    extrapolate: bool,
    monotone: bool,
}

impl LegacyDual {
    fn new(n: usize, p: usize, k: usize, extrapolate: bool, monotone: bool) -> Self {
        LegacyDual {
            buffer: ResidualBuffer::new(k.max(1)),
            theta: vec![0.0; n],
            xtheta: vec![0.0; p],
            dval: f64::NEG_INFINITY,
            y_norm_sq: f64::NAN,
            extrapolate,
            monotone,
        }
    }

    fn update(
        &mut self,
        x: &DesignMatrix,
        y: &[f64],
        lambda: f64,
        r: &[f64],
        scratch: &mut DualScratch,
    ) {
        self.buffer.push(r);
        let n = y.len();
        let p = x.p();
        scratch.xtr.resize(p, 0.0);
        if self.y_norm_sq.is_nan() {
            self.y_norm_sq = celer::util::linalg::dot(y, y);
        }
        let denom = lambda.max(x.xt_vec_abs_max(r, &mut scratch.xtr));
        let inv = 1.0 / denom;
        let d_res = {
            let mut dist_sq = 0.0;
            for i in 0..n {
                let d = r[i] * inv - y[i] / lambda;
                dist_sq += d * d;
            }
            0.5 * self.y_norm_sq - 0.5 * lambda * lambda * dist_sq
        };
        let mut best_val = d_res;
        let mut best_is_accel = false;
        if self.extrapolate && self.buffer.extrapolate_into(&mut scratch.extrap) {
            let r_acc = &scratch.extrap.r_accel;
            scratch.xtr_acc.resize(p, 0.0);
            scratch.theta_acc.resize(n, 0.0);
            let denom_a = lambda.max(x.xt_vec_abs_max(r_acc, &mut scratch.xtr_acc));
            let inv_a = 1.0 / denom_a;
            for (t, &v) in scratch.theta_acc.iter_mut().zip(r_acc.iter()) {
                *t = v * inv_a;
            }
            for v in scratch.xtr_acc.iter_mut() {
                *v *= inv_a;
            }
            let d_acc = dual::dual_objective_cached(y, &scratch.theta_acc, lambda, self.y_norm_sq);
            if d_acc > best_val {
                best_val = d_acc;
                best_is_accel = true;
            }
        }
        if self.monotone && self.dval >= best_val {
            return;
        }
        if best_is_accel {
            self.theta.clear();
            self.theta.extend_from_slice(&scratch.theta_acc);
            self.xtheta.clear();
            self.xtheta.extend_from_slice(&scratch.xtr_acc);
            self.dval = best_val;
        } else {
            self.theta.clear();
            self.theta.extend(r.iter().map(|&v| v * inv));
            self.xtheta.clear();
            self.xtheta.extend(scratch.xtr.iter().map(|&v| v * inv));
            self.dval = d_res;
        }
    }
}

struct LegacyOut {
    beta: Vec<f64>,
    r: Vec<f64>,
    theta: Vec<f64>,
    gap: f64,
    epochs: usize,
    converged: bool,
}

/// Faithful port of the pre-datafit `engine::solve` quadratic loop under
/// `StopRule::DualityGap` with `CdStrategy`: CD epochs over the active
/// set, gap checks every `gap_freq` epochs, hardcoded quadratic primal /
/// dual / Gap Safe screening, in the exact statement order of the old
/// engine.
#[allow(clippy::too_many_arguments)]
fn legacy_cd_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    tol: f64,
    max_epochs: usize,
    gap_freq: usize,
    k: usize,
    extrapolate: bool,
    screen: bool,
) -> LegacyOut {
    let n = x.n();
    let p = x.p();
    let mut norms_sq = vec![0.0; p];
    for (j, v) in norms_sq.iter_mut().enumerate() {
        *v = x.col_norm_sq(j);
    }
    let col_norms: Vec<f64> = norms_sq.iter().map(|v| v.sqrt()).collect();
    let mut beta = vec![0.0; p];
    let mut r = vec![0.0; n];
    primal::residual(x, y, &beta, &mut r);
    let mut active: Vec<usize> = (0..p).filter(|&j| norms_sq[j] > 0.0).collect();
    let mut dualst = LegacyDual::new(n, p, k.max(1), extrapolate, true);
    let mut scratch = DualScratch::default();
    scratch.prepare(n, p);
    let mut screened = vec![false; p];
    let mut scr_active: Vec<usize> = (0..p).collect();
    let mut r_check = vec![0.0; n];
    let mut gap = f64::INFINITY;
    let mut epochs = 0usize;
    let mut converged = false;
    for epoch in 1..=max_epochs {
        epochs = epoch;
        // ---- CdStrategy::epoch, verbatim ----
        for &j in &active {
            let nrm = norms_sq[j];
            let g = x.col_dot(j, &r);
            let old = beta[j];
            let new = celer::util::soft_threshold(old + g / nrm, lambda / nrm);
            if new != old {
                x.col_axpy(j, old - new, &mut r);
                beta[j] = new;
            }
        }
        if epoch % gap_freq == 0 || epoch == max_epochs {
            r_check.copy_from_slice(&r);
            dualst.update(x, y, lambda, &r_check, &mut scratch);
            let p_val = primal::primal_from_residual(&r_check, &beta, lambda);
            gap = p_val - dualst.dval;
            if screen && gap > tol {
                // ---- ScreeningState::screen, verbatim ----
                let radius = celer::screening::gap_safe_radius(gap, lambda);
                let threshold = radius + 1e-12;
                scr_active.retain(|&j| {
                    let keep = celer::screening::d_score(dualst.xtheta[j].abs(), col_norms[j])
                        <= threshold;
                    if !keep {
                        screened[j] = true;
                        if beta[j] != 0.0 {
                            x.col_axpy(j, beta[j], &mut r);
                            beta[j] = 0.0;
                        }
                    }
                    keep
                });
                active.retain(|&j| !screened[j]);
            }
            if gap <= tol {
                converged = true;
                break;
            }
        }
    }
    LegacyOut { beta, r, theta: dualst.theta, gap, epochs, converged }
}

fn assert_bitwise_equal_to_legacy(x: &DesignMatrix, y: &[f64], ratio: f64, screen: bool, extrapolate: bool) {
    let lambda = dual::lambda_max(x, y) * ratio;
    let cfg = CdConfig {
        tol: 1e-9,
        max_epochs: 2_000,
        gap_freq: 10,
        k: 5,
        extrapolate,
        best_dual: true,
        screen,
        // precision/trace: defaults (F64; the bitwise pin is the f64 path)
        ..Default::default()
    };
    let new = cd_solve(x, y, lambda, None, &cfg);
    let old = legacy_cd_solve(
        x, y, lambda, cfg.tol, cfg.max_epochs, cfg.gap_freq, cfg.k, extrapolate, screen,
    );
    assert_eq!(new.epochs, old.epochs, "epoch count");
    assert_eq!(new.converged, old.converged);
    assert_eq!(new.gap.to_bits(), old.gap.to_bits(), "gap bits");
    assert_eq!(new.beta.len(), old.beta.len());
    for j in 0..new.beta.len() {
        assert_eq!(new.beta[j].to_bits(), old.beta[j].to_bits(), "beta[{j}]");
    }
    for i in 0..new.r.len() {
        assert_eq!(new.r[i].to_bits(), old.r[i].to_bits(), "r[{i}]");
    }
    for i in 0..new.theta.len() {
        assert_eq!(new.theta[i].to_bits(), old.theta[i].to_bits(), "theta[{i}]");
    }
}

#[test]
fn quadratic_engine_bitwise_matches_prerefactor_dense() {
    let ds = synth::leukemia_mini(200);
    for &(screen, extrap) in &[(false, true), (true, true), (false, false), (true, false)] {
        assert_bitwise_equal_to_legacy(&ds.x, &ds.y, 0.1, screen, extrap);
    }
}

#[test]
fn quadratic_engine_bitwise_matches_prerefactor_sparse() {
    let ds = synth::finance_mini(201);
    for &(screen, extrap) in &[(false, true), (true, true)] {
        assert_bitwise_equal_to_legacy(&ds.x, &ds.y, 0.2, screen, extrap);
    }
}

#[test]
fn quadratic_celer_results_unchanged_by_datafit_threading() {
    // celer_solve runs through the datafit-generic outer loop with
    // Quadratic; its gap must still be an exactly recomputable
    // certificate of the returned (β, θ) pair, and the solution must
    // match a tight legacy-pinned CD solve on the objective.
    let ds = synth::leukemia_mini(202);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
    let cfg = celer::solvers::celer::CelerConfig { tol: 1e-10, ..Default::default() };
    let out = celer::solvers::celer::celer_solve_on(&ds.x, &ds.y, lambda, None, &cfg);
    assert!(out.result.converged);
    let p_val = primal::primal(&ds.x, &ds.y, &out.result.beta, lambda);
    let d_val = dual::dual_objective(&ds.y, &out.result.theta, lambda);
    assert!((p_val - d_val - out.gap()).abs() < 1e-12, "gap recomputes bitwise-close");
    let legacy = legacy_cd_solve(&ds.x, &ds.y, lambda, 1e-12, 50_000, 10, 5, true, false);
    assert!(legacy.converged);
    let p_legacy = primal::primal(&ds.x, &ds.y, &legacy.beta, lambda);
    assert!(p_val - p_legacy <= 2e-10, "celer {p_val} vs legacy CD {p_legacy}");
}

// ---------------------------------------------------------------------
// 3. logistic: gap-certified convergence vs unscreened reference
// ---------------------------------------------------------------------

#[test]
fn logreg_celer_gap_certified_against_unscreened_reference() {
    for seed in [210u64, 211] {
        let ds = synth::logreg_mini(seed);
        let lambda = logreg_lambda_max(&ds.x, &ds.y) / 12.0;
        let tol = 1e-9;
        let ws_out = sparse_logreg_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &celer::solvers::celer::CelerConfig { tol, ..Default::default() },
        );
        assert!(ws_out.result.converged, "seed {seed}: gap {}", ws_out.gap());
        assert!(ws_out.gap() <= tol);
        // unscreened, no-working-set reference at 10× tighter tolerance
        let reference = glm_cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &Logistic,
            &CdConfig { tol: tol / 10.0, screen: false, ..Default::default() },
        );
        assert!(reference.converged);
        let n = ds.x.n();
        let (mut xw, mut r) = (vec![0.0; n], vec![0.0; n]);
        primal::glm_state(&ds.x, &Logistic, &ds.y, &ws_out.result.beta, &mut xw, &mut r);
        let p_ws = primal::glm_primal_value(&Logistic, &ds.y, &xw, &r, &ws_out.result.beta, lambda);
        primal::glm_state(&ds.x, &Logistic, &ds.y, &reference.beta, &mut xw, &mut r);
        let p_ref = primal::glm_primal_value(&Logistic, &ds.y, &xw, &r, &reference.beta, lambda);
        // both gap-certified ⇒ objectives within the sum of tolerances
        assert!(
            (p_ws - p_ref).abs() <= 2.0 * tol,
            "seed {seed}: {p_ws} vs {p_ref}"
        );
        // the certificate is externally recomputable and feasible
        let d_val = Logistic.dual(&ds.y, &ws_out.result.theta, lambda, 0.0);
        assert!((p_ws - d_val - ws_out.gap()).abs() < 1e-9);
        assert!(dual::is_feasible(&ds.x, &ws_out.result.theta, 1e-9));
    }
}

#[test]
fn logreg_sparse_design_and_screening_safety() {
    // CSC storage through the same generic engine, with Gap Safe
    // screening (L = ¼ radius) proved harmless against the unscreened
    // run.
    let ds = synth::finance_mini(212);
    let y = synth::sign_labels(&ds.y);
    let lambda = logreg_lambda_max(&ds.x, &y) / 8.0;
    let tol = 1e-8;
    let plain = glm_cd_solve(&ds.x, &y, lambda, None, &Logistic, &CdConfig { tol, ..Default::default() });
    let screened = glm_cd_solve(
        &ds.x,
        &y,
        lambda,
        None,
        &Logistic,
        &CdConfig { tol, screen: true, ..Default::default() },
    );
    assert!(plain.converged && screened.converged);
    let n = ds.x.n();
    let (mut xw, mut r) = (vec![0.0; n], vec![0.0; n]);
    primal::glm_state(&ds.x, &Logistic, &y, &plain.beta, &mut xw, &mut r);
    let pa = primal::glm_primal_value(&Logistic, &y, &xw, &r, &plain.beta, lambda);
    primal::glm_state(&ds.x, &Logistic, &y, &screened.beta, &mut xw, &mut r);
    let pb = primal::glm_primal_value(&Logistic, &y, &xw, &r, &screened.beta, lambda);
    assert!((pa - pb).abs() <= 2.0 * tol, "{pa} vs {pb}");
}

#[test]
fn poisson_solves_certify_and_respect_domain() {
    let ds = synth::poisson_mini(213);
    let lambda = celer::solvers::glm::poisson_lambda_max(&ds.x, &ds.y) / 4.0;
    let tol = 1e-8;
    let out = celer::solvers::glm::sparse_poisson_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &celer::solvers::celer::CelerConfig { tol, ..Default::default() },
    );
    assert!(out.result.converged, "gap {}", out.gap());
    // dual point stays in the conjugate domain (yᵢ − λθᵢ ≥ 0)
    for i in 0..ds.y.len() {
        assert!(ds.y[i] - lambda * out.result.theta[i] >= -1e-12, "i={i}");
    }
    assert!(dual::is_feasible(&ds.x, &out.result.theta, 1e-9));
}

// ---------------------------------------------------------------------
// 4. path workspace reuse invariance
// ---------------------------------------------------------------------

#[test]
fn glm_path_workspace_reuse_is_bit_invariant() {
    let ds = synth::logreg_mini(220);
    let lmax = logreg_lambda_max(&ds.x, &ds.y);
    let grid = lambda_grid(lmax, 0.08, 5);
    let cfg = celer::solvers::celer::CelerConfig { tol: 1e-8, ..Default::default() };
    let mut fresh_ws = Workspace::new();
    let fresh =
        glm_path_with_workspace(&ds.x, &ds.y, GlmFamily::Logistic, &grid, &cfg, true, &mut fresh_ws);
    assert!(fresh.all_converged());
    // dirty the workspace with unrelated quadratic + GLM solves first
    let mut dirty_ws = Workspace::new();
    let quad = synth::leukemia_mini(220);
    let _ = celer::solvers::cd::cd_solve_ws(
        &quad.x,
        &quad.y,
        dual::lambda_max(&quad.x, &quad.y) / 3.0,
        None,
        &CdConfig::default(),
        &mut dirty_ws,
    );
    let _ = glm_path_with_workspace(
        &ds.x,
        &ds.y,
        GlmFamily::Logistic,
        &grid[..2],
        &cfg,
        false,
        &mut dirty_ws,
    );
    let reused =
        glm_path_with_workspace(&ds.x, &ds.y, GlmFamily::Logistic, &grid, &cfg, true, &mut dirty_ws);
    assert_eq!(fresh.steps.len(), reused.steps.len());
    for (a, b) in fresh.steps.iter().zip(&reused.steps) {
        assert_eq!(a.epochs, b.epochs);
        assert_eq!(a.gap.to_bits(), b.gap.to_bits());
        let (ba, bb) = (a.beta.as_ref().unwrap(), b.beta.as_ref().unwrap());
        for j in 0..ba.len() {
            assert_eq!(ba[j].to_bits(), bb[j].to_bits(), "λ={} j={j}", a.lambda);
        }
    }
}
