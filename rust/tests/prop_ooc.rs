//! Property tests for the out-of-core column store (`data::ooc`).
//!
//! The contracts pinned here:
//!
//! 1. **Storage is invisible to the math**: every `DesignOps` kernel on
//!    an `OocColumnStore` returns the exact bits the in-memory
//!    `CscMatrix` returns — single columns, lane ops, full scans — for
//!    any chunk size (one column per chunk up to everything-resident).
//! 2. **λ-path bit-identity** (the PR 9 acceptance criterion): a full
//!    lasso path solved on `DesignMatrix::Ooc` equals the path on
//!    `DesignMatrix::Sparse` bit-for-bit — per-step λ, gap certificate
//!    and β — under both serial and pooled execution, for the
//!    sequential and the batched (lane) scheduler.
//! 3. **Canonical bytes**: a dense-written and a sparse-written store
//!    of the same matrix are byte-identical files (explicit zeros are
//!    dropped), and `svmlight → store` equals `svmlight → CSC`.
//! 4. **Corruption is typed, not a panic**: truncated or corrupt
//!    headers fail `open` with `SolveError::StoreFormat`; non-finite
//!    payload values are caught by the validation gate as
//!    `SolveError::NonFiniteDesign`.

use celer::data::csc::CscMatrix;
use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::ooc::{self, OocColumnStore};
use celer::data::synth;
use celer::data::validate;
use celer::solvers::path::{lambda_grid, lasso_path, run_path, PathResult, PathSolver};
use celer::util::error::SolveError;
use celer::util::par;
use celer::util::rng::Rng;

/// Unique temp path per test so the suite can run in parallel.
fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("celer_prop_ooc_{}_{name}", std::process::id()))
}

struct TmpFile(std::path::PathBuf);
impl Drop for TmpFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn rand_vec(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

#[test]
fn every_kernel_matches_csc_bitwise_across_chunk_sizes() {
    let ds = synth::finance_mini(21);
    let DesignMatrix::Sparse(ref csc) = ds.x else { panic!("finance_mini is sparse") };
    let path = tmp("kernels.cstore");
    let _guard = TmpFile(path.clone());
    ooc::write_store(&path, csc, &ds.y).unwrap();

    let (n, p) = (csc.n(), csc.p());
    let v = rand_vec(22, n);
    let lanes: Vec<usize> = (0..4).collect();
    let vl = rand_vec(23, 4 * n);
    let alphas = [1e-3, -2e-3, 5e-4, -1e-4];

    // chunk sizes from "one column per chunk" to "everything resident"
    for chunk_bytes in [1usize, 1 << 10, 1 << 14, 1 << 30] {
        let store = OocColumnStore::open_with(&path, chunk_bytes, 3).unwrap();
        assert_eq!(store.read_labels().unwrap(), ds.y);
        for j in (0..p).step_by(7) {
            assert_eq!(
                store.col_dot(j, &v).to_bits(),
                csc.col_dot(j, &v).to_bits(),
                "col_dot j={j} chunk_bytes={chunk_bytes}"
            );
            assert_eq!(store.col_norm_sq(j).to_bits(), csc.col_norm_sq(j).to_bits());
            assert_eq!(store.col_nnz(j), csc.col_nnz(j));

            let mut out_s = [0.0f64; 4];
            let mut out_c = [0.0f64; 4];
            store.col_dot_lanes(j, &vl, n, &lanes, &mut out_s);
            csc.col_dot_lanes(j, &vl, n, &lanes, &mut out_c);
            assert_eq!(out_s.map(f64::to_bits), out_c.map(f64::to_bits), "lane dot j={j}");

            let mut vs = vl.clone();
            let mut vc = vl.clone();
            store.col_axpy_lanes(j, &alphas, &mut vs, n, &lanes);
            csc.col_axpy_lanes(j, &alphas, &mut vc, n, &lanes);
            assert_eq!(vs, vc, "lane axpy j={j}");
        }
        // full scans: pooled AND serial must equal the CSC scans
        let mut scan_s = vec![0.0; p];
        let mut scan_c = vec![0.0; p];
        store.xt_vec(&v, &mut scan_s);
        csc.xt_vec(&v, &mut scan_c);
        assert_eq!(scan_s, scan_c, "xt_vec chunk_bytes={chunk_bytes}");
        assert_eq!(store.xt_abs_max(&v).to_bits(), csc.xt_abs_max(&v).to_bits());
        assert_eq!(store.col_norms_sq(), csc.col_norms_sq());
        let serial = par::run_serial(|| {
            let mut out = vec![0.0; p];
            store.xt_vec(&v, &mut out);
            out
        });
        assert_eq!(serial, scan_c, "serial ooc scan == csc scan");
        // working-set restriction and full materialization round-trip
        let keep: Vec<usize> = (0..p).step_by(11).collect();
        let sub_s = store.select_columns_csc(&keep);
        let sub_c = csc.select_columns(&keep);
        for (jj, _) in keep.iter().enumerate() {
            assert_eq!(sub_s.col(jj), sub_c.col(jj));
        }
        let round = store.to_csc();
        for j in 0..p {
            assert_eq!(round.col(j), csc.col(j), "to_csc col {j}");
        }
    }
}

fn assert_paths_bit_identical(a: &PathResult, b: &PathResult, what: &str) {
    assert_eq!(a.steps.len(), b.steps.len(), "{what}: step count");
    for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
        assert_eq!(sa.lambda.to_bits(), sb.lambda.to_bits(), "{what}: λ#{i}");
        assert_eq!(sa.gap.to_bits(), sb.gap.to_bits(), "{what}: gap#{i}");
        let ba = sa.beta.as_ref().expect("store_betas");
        let bb = sb.beta.as_ref().expect("store_betas");
        let diff = ba.iter().zip(bb).position(|(x, y)| x.to_bits() != y.to_bits());
        assert_eq!(diff, None, "{what}: β#{i} first differing coefficient {diff:?}");
    }
}

#[test]
fn lambda_path_on_store_is_bit_identical_to_in_memory() {
    // The acceptance criterion: same λ-grid solved on the on-disk store
    // and on the resident CSC must produce identical certificates.
    let ds = synth::finance_mini(31);
    let path = tmp("path.cstore");
    let _guard = TmpFile(path.clone());
    ooc::write_store(&path, &ds.x, &ds.y).unwrap();
    // tiny chunks: the path genuinely streams (hundreds of chunks)
    let store = OocColumnStore::open_with(&path, 1 << 12, 3).unwrap();
    assert!(store.nchunks() > 4, "want a chunked store, got {}", store.nchunks());
    let x_ooc = DesignMatrix::Ooc(store);

    let lam_max = celer::lasso::dual::lambda_max(&ds.x, &ds.y);
    let grid = lambda_grid(lam_max, 0.1, 6);
    let solver = PathSolver::by_name("gapsafe-cd-accel", 1e-9).unwrap();

    // sequential scheduler, pooled then serial
    let mem = run_path(&ds.x, &ds.y, &grid, &solver, true);
    let ooc_run = run_path(&x_ooc, &ds.y, &grid, &solver, true);
    assert!(mem.all_converged());
    assert_paths_bit_identical(&mem, &ooc_run, "sequential pooled");
    let (mem_s, ooc_s) = par::run_serial(|| {
        (
            run_path(&ds.x, &ds.y, &grid, &solver, true),
            run_path(&x_ooc, &ds.y, &grid, &solver, true),
        )
    });
    assert_paths_bit_identical(&mem_s, &ooc_s, "sequential serial");
    assert_paths_bit_identical(&mem, &mem_s, "pooled vs serial (in-memory)");

    // batched lane scheduler over the same store
    let mem_b = lasso_path(&ds.x, &ds.y, &grid, 1e-9, 3, true, &celer::penalty::L1);
    let ooc_b = lasso_path(&x_ooc, &ds.y, &grid, 1e-9, 3, true, &celer::penalty::L1);
    assert!(mem_b.all_converged());
    assert_paths_bit_identical(&mem_b, &ooc_b, "batched pooled");
}

#[test]
fn dense_written_and_sparse_written_stores_are_byte_identical() {
    let ds = synth::leukemia_mini(41);
    // leukemia_mini is dense; build the equivalent CSC by materializing
    let DesignMatrix::Dense(ref dm) = ds.x else { panic!("leukemia_mini is dense") };
    let (n, p) = (dm.n(), dm.p());
    let csc = CscMatrix::from_dense(n, p, dm.raw());
    let pd = tmp("dense_written.cstore");
    let ps = tmp("sparse_written.cstore");
    let _g1 = TmpFile(pd.clone());
    let _g2 = TmpFile(ps.clone());
    let md = ooc::write_store(&pd, dm, &ds.y).unwrap();
    let ms = ooc::write_store(&ps, &csc, &ds.y).unwrap();
    assert_eq!(md, ms, "meta");
    let bd = std::fs::read(&pd).unwrap();
    let bs = std::fs::read(&ps).unwrap();
    assert_eq!(bd, bs, "files differ");
}

#[test]
fn svmlight_roundtrips_through_the_store_converter() {
    let ds = synth::finance_mini(51);
    let svm = tmp("conv.svm");
    let cst = tmp("conv.cstore");
    let _g1 = TmpFile(svm.clone());
    let _g2 = TmpFile(cst.clone());
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&svm).unwrap());
        let dset = celer::data::svmlight::Dataset { x: ds.x, y: ds.y };
        celer::data::svmlight::write_svmlight(&mut f, &dset).unwrap();
    }
    let meta = ooc::svmlight_to_store(&svm, &cst, 0).unwrap();
    // reference: the same svmlight text through the in-memory parser
    let parsed = celer::data::svmlight::load_svmlight(&svm).unwrap();
    let DesignMatrix::Sparse(ref csc) = parsed.x else { panic!() };
    assert_eq!((meta.n, meta.p, meta.nnz), (csc.n(), csc.p(), csc.nnz()));
    let (store, y) = OocColumnStore::open_dataset(&cst).unwrap();
    assert_eq!(y, parsed.y);
    let round = store.to_csc();
    for j in 0..csc.p() {
        assert_eq!(round.col(j), csc.col(j), "converted col {j}");
    }
}

#[test]
fn corrupt_and_truncated_stores_fail_typed() {
    let ds = synth::finance_mini(61);
    let path = tmp("corrupt.cstore");
    let _guard = TmpFile(path.clone());
    ooc::write_store(&path, &ds.x, &ds.y).unwrap();
    let good = std::fs::read(&path).unwrap();

    let expect_format = |bytes: &[u8], what: &str| {
        std::fs::write(&path, bytes).unwrap();
        match OocColumnStore::open(&path) {
            Err(SolveError::StoreFormat { .. }) => {}
            other => panic!("{what}: expected StoreFormat, got {other:?}"),
        }
    };
    // header cut mid-field
    expect_format(&good[..17], "truncated header");
    // payload cut: advertised nnz no longer fits the file
    expect_format(&good[..good.len() - 5], "truncated payload");
    // wrong magic
    let mut bad = good.clone();
    bad[0] ^= 0xFF;
    expect_format(&bad, "bad magic");
    // unknown version
    let mut bad = good.clone();
    bad[8] = 99;
    expect_format(&bad, "bad version");
    // corrupt column index: indptr[0] stomped (must be 0)
    let mut bad = good.clone();
    let n = ds.y.len();
    let indptr0 = 40 + 8 * n; // header + y segment
    bad[indptr0..indptr0 + 8].copy_from_slice(&u64::MAX.to_le_bytes());
    expect_format(&bad, "corrupt indptr[0]");
    // non-monotone column index: indptr[1] pushed past indptr[2]
    let mut bad = good.clone();
    bad[indptr0 + 8..indptr0 + 16].copy_from_slice(&u64::MAX.to_le_bytes());
    expect_format(&bad, "non-monotone indptr");

    // a missing file is also a typed error, not a panic
    let gone = tmp("never_written.cstore");
    assert!(matches!(
        OocColumnStore::open(&gone),
        Err(SolveError::StoreFormat { .. })
    ));
}

#[test]
fn validation_gate_catches_nonfinite_payload() {
    let ds = synth::finance_mini(71);
    let path = tmp("nonfinite.cstore");
    let _guard = TmpFile(path.clone());
    let meta = ooc::write_store(&path, &ds.x, &ds.y).unwrap();
    // the poisoned entry (the store's last) lives in the last column
    // holding any entries at all
    let DesignMatrix::Sparse(ref csc) = ds.x else { panic!() };
    let last_nonempty = (0..csc.p()).rev().find(|&j| csc.col_nnz(j) > 0).unwrap();
    // poison one stored value: last f64 of the data segment
    let mut bytes = std::fs::read(&path).unwrap();
    let off = bytes.len() - 8;
    bytes[off..].copy_from_slice(&f64::NAN.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    let store = OocColumnStore::open(&path).unwrap();
    assert_eq!(store.meta(), meta, "header still valid");
    match validate::validate_design(&DesignMatrix::Ooc(store)) {
        Err(SolveError::NonFiniteDesign { col, .. }) => {
            assert_eq!(col, last_nonempty, "poisoned entry sits in the last stored column");
        }
        other => panic!("expected NonFiniteDesign, got {other:?}"),
    }
}
