//! Property tests for the Multi-Task Lasso port onto the block engine
//! (paper §7):
//!
//! 1. **Legacy equivalence** — the engine-ported `mt_celer_solve` against
//!    a faithful test-local port of the pre-refactor strided solver
//!    (row-major kernels, `select_columns` materialization, its own
//!    gap-check loop): both gap-certified, identical row supports,
//!    objectives within 2ε — dense and CSC designs.
//! 2. **q = 1 bit-identity** — the block engine at width 1 is the scalar
//!    engine, bit for bit (β, r, θ, gap, epochs), dense and sparse,
//!    screening on and off.
//! 3. **Workspace-reuse invariance** — an MT λ path is bit-identical on
//!    a fresh vs. a dirtied workspace.
//! 4. **Pooled ≡ serial** — MT solves above the parallel work threshold
//!    are bit-identical under `par::run_serial` (with the CI
//!    `CELER_NUM_THREADS ∈ {1, 4}` matrix this pins thread invariance).
//! 5. **View ≡ materialized** — block inner solves on a zero-copy
//!    `DesignView` match solves on a `select_columns` copy bitwise.

use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::view::DesignView;
use celer::multitask::solver::{
    mt_bcd_solve, mt_celer_solve, mt_lambda_max, mt_primal, MtConfig,
};
use celer::multitask::TaskMatrix;
use celer::solvers::block::{solve_blocks, BlockCdStrategy, BlockWorkspace};
use celer::solvers::engine::{solve, CdStrategy, EngineConfig, Init, StopRule, Workspace};
use celer::solvers::path::{lambda_grid, run_mt_path, run_mt_path_with_workspace};
use celer::util::rng::Rng;

fn engine_cfg(tol: f64, screen: bool) -> EngineConfig {
    EngineConfig {
        tol,
        max_epochs: 20_000,
        gap_freq: 10,
        k: 5,
        extrapolate: true,
        best_dual: true,
        screen,
        trace: false,
        stop: StopRule::DualityGap,
        ..EngineConfig::default()
    }
}

/// Random unit-column dense design + row-major n×q targets.
fn random_mt_dense(seed: u64, n: usize, p: usize, q: usize) -> (DesignMatrix, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut data = vec![0.0; n * p];
    for v in data.iter_mut() {
        *v = rng.normal();
    }
    for j in 0..p {
        let nrm: f64 = data[j * n..(j + 1) * n].iter().map(|v| v * v).sum::<f64>().sqrt();
        for v in data[j * n..(j + 1) * n].iter_mut() {
            *v /= nrm;
        }
    }
    let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
    (
        DesignMatrix::Dense(celer::data::dense::DenseMatrix::from_col_major(n, p, data)),
        y,
    )
}

/// Random sparse (CSC) design + row-major n×q targets.
fn random_mt_sparse(
    seed: u64,
    n: usize,
    p: usize,
    q: usize,
    density: f64,
) -> (DesignMatrix, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut data = vec![0.0; n * p];
    for v in data.iter_mut() {
        if rng.uniform() < density {
            *v = rng.normal();
        }
    }
    let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
    (
        DesignMatrix::Sparse(celer::data::csc::CscMatrix::from_dense(n, p, &data)),
        y,
    )
}

/// Faithful port of the pre-refactor Multi-Task solver (the code this PR
/// replaced): strided row-major kernels over a dense column-major copy,
/// `select_columns` materialization for every working set, and its own
/// gap-check / extrapolation loop. Kept here as the independent oracle.
mod reference {
    use celer::extrapolation::ResidualBuffer;
    use celer::multitask::solver::{mt_dual, mt_primal, MtConfig};
    use celer::multitask::{block_soft_threshold, TaskMatrix};
    use celer::util::select::k_smallest_indices;

    /// Dense column-major design with the legacy strided kernels.
    pub struct DenseRef {
        pub n: usize,
        pub p: usize,
        data: Vec<f64>,
    }

    impl DenseRef {
        pub fn from_design(x: &celer::data::design::DesignMatrix) -> Self {
            use celer::data::design::DesignOps;
            let (n, p) = (x.n(), x.p());
            let mut data = Vec::new();
            x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut data);
            DenseRef { n, p, data }
        }

        fn col(&self, j: usize) -> &[f64] {
            &self.data[j * self.n..(j + 1) * self.n]
        }

        fn col_dot_strided(&self, j: usize, m: &[f64], q: usize, t: usize) -> f64 {
            let mut acc = 0.0;
            for (i, &v) in self.col(j).iter().enumerate() {
                acc += v * m[i * q + t];
            }
            acc
        }

        fn col_axpy_strided(&self, j: usize, alpha: f64, m: &mut [f64], q: usize, t: usize) {
            let col = self.col(j);
            for (i, &v) in col.iter().enumerate() {
                m[i * q + t] += alpha * v;
            }
        }

        fn col_norms_sq(&self) -> Vec<f64> {
            (0..self.p).map(|j| self.col(j).iter().map(|v| v * v).sum()).collect()
        }

        fn select_columns(&self, cols: &[usize]) -> DenseRef {
            let mut data = Vec::with_capacity(cols.len() * self.n);
            for &j in cols {
                data.extend_from_slice(self.col(j));
            }
            DenseRef { n: self.n, p: cols.len(), data }
        }
    }

    fn xt_theta_row_norms(x: &DenseRef, theta: &[f64], q: usize, out: &mut [f64]) {
        for (j, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0;
            for t in 0..q {
                let v = x.col_dot_strided(j, theta, q, t);
                acc += v * v;
            }
            *o = acc.sqrt();
        }
    }

    pub struct RefResult {
        pub b: TaskMatrix,
        pub r: Vec<f64>,
        pub theta: Vec<f64>,
        pub gap: f64,
        pub converged: bool,
    }

    /// The legacy cyclic block-CD loop (row-major residual).
    pub fn bcd_solve(
        x: &DenseRef,
        y: &[f64],
        q: usize,
        lambda: f64,
        b0: Option<&TaskMatrix>,
        cfg: &MtConfig,
    ) -> RefResult {
        let (n, p) = (x.n, x.p);
        assert_eq!(y.len(), n * q);
        let mut b = b0.cloned().unwrap_or_else(|| TaskMatrix::zeros(p, q));
        let mut r = y.to_vec();
        for j in 0..p {
            for t in 0..q {
                let v = b.row(j)[t];
                if v != 0.0 {
                    x.col_axpy_strided(j, -v, &mut r, q, t);
                }
            }
        }
        let norms_sq = x.col_norms_sq();
        let mut buffer = ResidualBuffer::new(cfg.k);
        let mut best_theta = vec![0.0; n * q];
        let mut best_dual = f64::NEG_INFINITY;
        let mut gap = f64::INFINITY;
        let mut converged = false;
        let mut row_norms = vec![0.0; p];
        let mut u = vec![0.0; q];

        for epoch in 1..=cfg.max_epochs {
            for j in 0..p {
                let nrm = norms_sq[j];
                if nrm == 0.0 {
                    continue;
                }
                for t in 0..q {
                    u[t] = b.row(j)[t] + x.col_dot_strided(j, &r, q, t) / nrm;
                }
                block_soft_threshold(&mut u, lambda / nrm);
                for t in 0..q {
                    let old = b.row(j)[t];
                    let delta = u[t] - old;
                    if delta != 0.0 {
                        x.col_axpy_strided(j, -delta, &mut r, q, t);
                        b.row_mut(j)[t] = u[t];
                    }
                }
            }
            if epoch % cfg.gap_freq == 0 || epoch == cfg.max_epochs {
                buffer.push(&r);
                let mut cands: Vec<Vec<f64>> = vec![r.clone()];
                if cfg.extrapolate {
                    if let Some(acc) = buffer.extrapolate() {
                        cands.push(acc);
                    }
                }
                for cand in cands {
                    xt_theta_row_norms(x, &cand, q, &mut row_norms);
                    let denom = row_norms.iter().fold(lambda, |m, &v| m.max(v));
                    let theta: Vec<f64> = cand.iter().map(|&v| v / denom).collect();
                    let d = mt_dual(y, &theta, lambda);
                    if d > best_dual {
                        best_dual = d;
                        best_theta = theta;
                    }
                }
                gap = mt_primal(&r, &b, lambda) - best_dual;
                if gap <= cfg.tol {
                    converged = true;
                    break;
                }
            }
        }
        RefResult { b, r, theta: best_theta, gap, converged }
    }

    /// The legacy working-set loop: `select_columns` materialization of
    /// every `X_{W_t}`, warm-started legacy BCD subproblems.
    pub fn celer_solve(
        x: &DenseRef,
        y: &[f64],
        q: usize,
        lambda: f64,
        cfg: &MtConfig,
    ) -> RefResult {
        let (n, p) = (x.n, x.p);
        let col_norms: Vec<f64> = x.col_norms_sq().iter().map(|v| v.sqrt()).collect();
        let mut b = TaskMatrix::zeros(p, q);
        let mut r = y.to_vec();
        let mut theta = {
            let mut row_norms = vec![0.0; p];
            xt_theta_row_norms(x, y, q, &mut row_norms);
            let lmax = row_norms.iter().fold(0.0f64, |m, &v| m.max(v)).max(f64::MIN_POSITIVE);
            y.iter().map(|&v| v / lmax).collect::<Vec<f64>>()
        };
        let mut gap = f64::INFINITY;
        let mut converged = false;
        let mut row_norms = vec![0.0; p];
        let mut prev_ws_len = 0usize;

        for t_out in 1..=50 {
            xt_theta_row_norms(x, &r, q, &mut row_norms);
            let denom = row_norms.iter().fold(lambda, |m, &v| m.max(v));
            let theta_res: Vec<f64> = r.iter().map(|&v| v / denom).collect();
            if mt_dual(y, &theta_res, lambda) > mt_dual(y, &theta, lambda) {
                theta.copy_from_slice(&theta_res);
            }
            gap = mt_primal(&r, &b, lambda) - mt_dual(y, &theta, lambda);
            if gap <= cfg.tol {
                converged = true;
                break;
            }

            xt_theta_row_norms(x, &theta_res, q, &mut row_norms);
            let mut scores: Vec<f64> = (0..p)
                .map(|j| {
                    if col_norms[j] == 0.0 {
                        f64::MAX
                    } else {
                        (1.0 - row_norms[j]) / col_norms[j]
                    }
                })
                .collect();
            let support = b.support();
            for &j in &support {
                scores[j] = -1.0;
            }
            let stagnated = t_out >= 2 && prev_ws_len > 0;
            let pt = if t_out == 1 {
                100.min(p)
            } else {
                (2 * support.len().max(1)).max(if stagnated { prev_ws_len } else { 0 }).min(p)
            }
            .max(support.len());
            let mut ws = k_smallest_indices(&scores, pt);
            ws.sort_unstable();
            prev_ws_len = ws.len();

            let x_ws = x.select_columns(&ws);
            let mut b_ws = TaskMatrix::zeros(ws.len(), q);
            for (i, &j) in ws.iter().enumerate() {
                b_ws.row_mut(i).copy_from_slice(b.row(j));
            }
            let inner_cfg = MtConfig { tol: 0.3 * gap, ..cfg.clone() };
            let inner = bcd_solve(&x_ws, y, q, lambda, Some(&b_ws), &inner_cfg);
            b = TaskMatrix::zeros(p, q);
            for (i, &j) in ws.iter().enumerate() {
                b.row_mut(j).copy_from_slice(inner.b.row(i));
            }
            r.copy_from_slice(&inner.r);
            xt_theta_row_norms(x, &inner.theta, q, &mut row_norms);
            let s = row_norms.iter().fold(1.0f64, |m, &v| m.max(v));
            let lifted: Vec<f64> = inner.theta.iter().map(|&v| v / s).collect();
            if mt_dual(y, &lifted, lambda) > mt_dual(y, &theta, lambda) {
                theta = lifted;
            }
        }
        let _ = n;
        RefResult { b, r, theta, gap, converged }
    }
}

fn check_legacy_equivalence(x: &DesignMatrix, y: &[f64], q: usize, ratio: f64, tol: f64) {
    let lambda = mt_lambda_max(x, y, q) * ratio;
    let cfg = MtConfig { tol, ..Default::default() };
    let new = mt_celer_solve(x, y, q, lambda, &cfg);
    assert!(new.converged, "engine-ported MT converged, gap {}", new.gap);
    assert!(new.gap <= tol);
    let xd = reference::DenseRef::from_design(x);
    let old = reference::celer_solve(&xd, y, q, lambda, &cfg);
    assert!(old.converged, "legacy MT converged, gap {}", old.gap);
    // identical row supports at the certification resolution
    assert_eq!(new.b.support(), old.b.support(), "row supports");
    // gap-certified objectives agree within 2ε
    let p_new = mt_primal(&new.r, &new.b, lambda);
    let p_old = mt_primal(&old.r, &old.b, lambda);
    assert!((p_new - p_old).abs() <= 2.0 * tol, "{p_new} vs {p_old}");
}

#[test]
fn legacy_equivalence_dense() {
    let (x, y) = random_mt_dense(100, 24, 64, 3);
    check_legacy_equivalence(&x, &y, 3, 0.2, 1e-9);
    check_legacy_equivalence(&x, &y, 3, 0.08, 1e-9);
}

#[test]
fn legacy_equivalence_sparse() {
    let (x, y) = random_mt_sparse(101, 30, 80, 4, 0.3);
    check_legacy_equivalence(&x, &y, 4, 0.2, 1e-9);
}

#[test]
fn legacy_equivalence_bcd() {
    // The full-design block-CD solver against the legacy strided loop.
    let (x, y) = random_mt_dense(102, 20, 40, 2);
    let lambda = mt_lambda_max(&x, &y, 2) / 6.0;
    let cfg = MtConfig { tol: 1e-10, ..Default::default() };
    let new = mt_bcd_solve(&x, &y, 2, lambda, None, &cfg);
    let xd = reference::DenseRef::from_design(&x);
    let old = reference::bcd_solve(&xd, &y, 2, lambda, None, &cfg);
    assert!(new.converged && old.converged);
    assert_eq!(new.b.support(), old.b.support());
    let (pn, po) = (mt_primal(&new.r, &new.b, lambda), mt_primal(&old.r, &old.b, lambda));
    assert!((pn - po).abs() <= 2e-10, "{pn} vs {po}");
}

#[test]
fn q1_block_engine_bitwise_scalar_engine() {
    // Width-1 blocks ARE the scalar engine: same kernels, same order,
    // same bits — dense and sparse, screening on and off.
    for (ds, tag) in [
        (celer::data::synth::leukemia_mini(110), "dense"),
        (celer::data::synth::finance_mini(110), "sparse"),
    ] {
        let lambda = celer::lasso::dual::lambda_max(&ds.x, &ds.y) / 10.0;
        for screen in [false, true] {
            let cfg = engine_cfg(1e-9, screen);
            let mut sws = Workspace::new();
            let a = solve(&ds.x, &ds.y, lambda, Init::Zeros, None, &cfg, &mut sws, &mut CdStrategy);
            let mut bws = BlockWorkspace::new();
            let b = solve_blocks(
                &ds.x,
                &ds.y,
                1,
                lambda,
                Init::Zeros,
                None,
                &cfg,
                &mut bws,
                &mut BlockCdStrategy,
            );
            assert_eq!(a.epochs, b.epochs, "{tag} screen={screen}");
            assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "{tag} screen={screen}");
            assert_eq!(a.converged, b.converged);
            assert_eq!(sws.beta, bws.beta, "{tag} screen={screen}: β bits");
            assert_eq!(sws.r, bws.r, "{tag} screen={screen}: r bits");
            assert_eq!(sws.dual.theta, bws.dual.theta, "{tag} screen={screen}: θ bits");
        }
    }
}

#[test]
fn mt_path_workspace_reuse_invariance() {
    // One warm-started MT λ path, fresh workspace vs. a workspace dirtied
    // by unrelated solves: bit-identical trajectories, dense and sparse.
    let cases = [(random_mt_dense(120, 20, 48, 3), 3), (random_mt_sparse(121, 24, 60, 2, 0.35), 2)];
    for (pair, q) in cases {
        let (x, y) = pair;
        let lmax = mt_lambda_max(&x, &y, q);
        let grid = lambda_grid(lmax, 0.1, 6);
        let cfg = MtConfig { tol: 1e-8, ..Default::default() };
        let fresh = run_mt_path(&x, &y, q, &grid, &cfg, true);
        assert!(fresh.all_converged());
        let mut ws = Workspace::new();
        // dirty: a scalar solve plus a truncated MT path at another width
        let y1: Vec<f64> = y.iter().take(x.n()).copied().collect();
        let _ = run_mt_path_with_workspace(&x, &y1, 1, &grid[..2], &cfg, false, &mut ws);
        let reused = run_mt_path_with_workspace(&x, &y, q, &grid, &cfg, true, &mut ws);
        for (a, b) in fresh.steps.iter().zip(&reused.steps) {
            assert_eq!(a.epochs, b.epochs);
            assert_eq!(a.gap.to_bits(), b.gap.to_bits());
            assert_eq!(a.b.as_ref().unwrap().data, b.b.as_ref().unwrap().data);
        }
    }
}

#[test]
fn pooled_matches_serial_scope_bitwise() {
    // MT solves whose pricing scans clear the parallel work threshold
    // (p = 8192): pooled and serial-scope runs must agree bit for bit.
    // Under the CI thread matrix (CELER_NUM_THREADS = 1 and 4) this pins
    // thread-count invariance of the block engine end to end.
    let ds = celer::data::synth::dense_scan_stress(130);
    let (n, q) = (ds.x.n(), 4);
    let mut rng = Rng::new(7);
    let y: Vec<f64> = (0..n * q).map(|_| rng.normal()).collect();
    let lambda = mt_lambda_max(&ds.x, &y, q) / 5.0;
    let cfg = MtConfig { tol: 1e-6, ..Default::default() };
    let pooled = mt_celer_solve(&ds.x, &y, q, lambda, &cfg);
    let serial = celer::util::par::run_serial(|| mt_celer_solve(&ds.x, &y, q, lambda, &cfg));
    assert_eq!(pooled.epochs, serial.epochs);
    assert_eq!(pooled.gap.to_bits(), serial.gap.to_bits());
    assert_eq!(pooled.b.data, serial.b.data);
    assert_eq!(pooled.r, serial.r);
}

#[test]
fn block_view_matches_materialized_bitwise() {
    // A block inner solve on a zero-copy DesignView equals the same
    // solve on a select_columns copy, bit for bit (the MT hot-path
    // guarantee: views changed the storage access, not the arithmetic).
    for (x, y, q) in [
        {
            let (x, y) = random_mt_dense(140, 18, 30, 3);
            (x, y, 3)
        },
        {
            let (x, y) = random_mt_sparse(141, 22, 36, 2, 0.4);
            (x, y, 2)
        },
    ] {
        let n = x.n();
        let cols = [1usize, 4, 7, 11, 18, 25];
        let norms = x.col_norms_sq();
        let lambda = mt_lambda_max(&x, &y, q) / 20.0;
        // lane-major targets for the raw engine entry
        let mut y_lanes = Vec::new();
        celer::multitask::rowmajor_to_lanes(&y, n, q, &mut y_lanes);
        let cfg = engine_cfg(1e-10, false);

        let mut ws_view = BlockWorkspace::new();
        let view = DesignView::new(&x, &cols, &norms);
        let a = solve_blocks(
            &view,
            &y_lanes,
            q,
            lambda,
            Init::Zeros,
            None,
            &cfg,
            &mut ws_view,
            &mut BlockCdStrategy,
        );

        let mut ws_mat = BlockWorkspace::new();
        let sub = x.select_columns(&cols);
        let b = solve_blocks(
            &sub,
            &y_lanes,
            q,
            lambda,
            Init::Zeros,
            None,
            &cfg,
            &mut ws_mat,
            &mut BlockCdStrategy,
        );

        assert_eq!(a.epochs, b.epochs);
        assert_eq!(a.gap.to_bits(), b.gap.to_bits());
        assert_eq!(ws_view.beta, ws_mat.beta, "β bits");
        assert_eq!(ws_view.r, ws_mat.r, "residual bits");
        assert_eq!(ws_view.dual.theta, ws_mat.dual.theta, "θ bits");
    }
}

#[test]
fn celer_mt_certificate_is_recomputable() {
    // The returned (B, Θ, gap) triple is a genuine certificate: Θ is
    // dual-feasible and the gap claim recomputes from the public
    // helpers (row-major recompute ⇒ summation-order tolerance).
    let (x, y) = random_mt_dense(150, 22, 50, 3);
    let lambda = mt_lambda_max(&x, &y, 3) / 7.0;
    let out = mt_celer_solve(&x, &y, 3, lambda, &MtConfig { tol: 1e-9, ..Default::default() });
    assert!(out.converged);
    let mut rows = vec![0.0; 50];
    celer::multitask::solver::mt_xt_row_norms(&x, &out.theta, 3, &mut rows);
    assert!(rows.iter().all(|&v| v <= 1.0 + 1e-9), "dual feasible");
    let g = mt_primal(&out.r, &out.b, lambda)
        - celer::multitask::solver::mt_dual(&y, &out.theta, lambda);
    assert!((g - out.gap).abs() < 1e-9, "{g} vs {}", out.gap);
    // row-sparse structure survives the working-set lift
    let b: &TaskMatrix = &out.b;
    for j in 0..50 {
        let nz = b.row(j).iter().filter(|&&v| v != 0.0).count();
        assert!(nz == 0 || nz == 3, "row {j}");
    }
}
