//! Cross-solver integration: every solver must find the same optimum on
//! the same problem, across dense/sparse data and a range of λ.

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::{dual, kkt, primal};
use celer::solvers::blitz::{blitz_solve, BlitzConfig};
use celer::solvers::cd::{cd_solve, CdConfig};
use celer::solvers::celer::{celer_solve_on, CelerConfig};
use celer::solvers::glmnet::{glmnet_solve, GlmnetConfig};
use celer::solvers::ista::{ista_solve, IstaConfig};

fn objectives_on(ds: &synth::SynthDataset, ratio: f64, tol: f64) -> Vec<(String, f64)> {
    let lmax = dual::lambda_max(&ds.x, &ds.y);
    let lambda = lmax * ratio;
    let mut out = Vec::new();

    let celer = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig { tol, ..Default::default() });
    assert!(celer.result.converged, "celer gap {}", celer.gap());
    out.push(("celer-prune".into(), primal::primal(&ds.x, &ds.y, &celer.result.beta, lambda)));

    let safe = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig { tol, ..CelerConfig::safe() });
    assert!(safe.result.converged, "celer-safe gap {}", safe.gap());
    out.push(("celer-safe".into(), primal::primal(&ds.x, &ds.y, &safe.result.beta, lambda)));

    let blitz = blitz_solve(&ds.x, &ds.y, lambda, None, &BlitzConfig { tol, ..Default::default() });
    assert!(blitz.result.converged, "blitz gap {}", blitz.result.gap);
    out.push(("blitz".into(), primal::primal(&ds.x, &ds.y, &blitz.result.beta, lambda)));

    let cd = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol, ..CdConfig::vanilla() });
    assert!(cd.converged);
    out.push(("cd".into(), primal::primal(&ds.x, &ds.y, &cd.beta, lambda)));

    let screen = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol, screen: true, ..Default::default() });
    assert!(screen.converged);
    out.push(("gapsafe-cd".into(), primal::primal(&ds.x, &ds.y, &screen.beta, lambda)));

    let glm = glmnet_solve(
        &ds.x,
        &ds.y,
        lambda,
        lmax,
        None,
        &GlmnetConfig { tol: tol / 100.0, ..Default::default() },
    );
    out.push(("glmnet".into(), primal::primal(&ds.x, &ds.y, &glm.beta, lambda)));

    out
}

#[test]
fn all_solvers_agree_dense() {
    let ds = synth::leukemia_mini(100);
    for ratio in [0.5, 0.2, 0.05] {
        let objs = objectives_on(&ds, ratio, 1e-9);
        let best = objs.iter().map(|(_, p)| *p).fold(f64::INFINITY, f64::min);
        for (name, p) in &objs {
            assert!(p - best < 1e-6, "{name} at ratio {ratio}: {p} vs best {best}");
        }
    }
}

#[test]
fn all_solvers_agree_sparse() {
    let ds = synth::finance_mini(101);
    for ratio in [0.3, 0.1] {
        let objs = objectives_on(&ds, ratio, 1e-8);
        let best = objs.iter().map(|(_, p)| *p).fold(f64::INFINITY, f64::min);
        for (name, p) in &objs {
            assert!(p - best < 1e-5, "{name} at ratio {ratio}: {p} vs best {best}");
        }
    }
}

#[test]
fn ista_fista_cd_same_solution() {
    let ds = synth::leukemia_mini(102);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 8.0;
    let cd = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol: 1e-10, ..Default::default() });
    let ista = ista_solve(&ds.x, &ds.y, lambda, None, &IstaConfig { tol: 1e-10, ..Default::default() });
    let fista = ista_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &IstaConfig { tol: 1e-10, fista: true, ..Default::default() },
    );
    let p = |b: &[f64]| primal::primal(&ds.x, &ds.y, b, lambda);
    assert!((p(&cd.beta) - p(&ista.beta)).abs() < 1e-8);
    assert!((p(&cd.beta) - p(&fista.beta)).abs() < 1e-8);
}

#[test]
fn solutions_satisfy_kkt_and_duality() {
    let ds = synth::finance_mini(103);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 6.0;
    let out = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig { tol: 1e-10, ..Default::default() });
    assert!(out.result.converged);
    let viol = kkt::max_violation(&ds.x, &out.result.r, &out.result.beta, lambda);
    assert!(viol < 1e-4, "KKT violation {viol}");
    assert!(dual::is_feasible(&ds.x, &out.result.theta, 1e-9));
    let gap = primal::primal(&ds.x, &ds.y, &out.result.beta, lambda)
        - dual::dual_objective(&ds.y, &out.result.theta, lambda);
    assert!(gap <= 1e-9, "gap {gap}");
    assert!(gap >= -1e-12, "weak duality");
}

#[test]
fn celer_beats_vanilla_cd_wall_clock() {
    // the paper's core speed claim on the paper-scale dense dataset
    let ds = synth::leukemia_sim(104);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 20.0;
    let tol = 1e-8;
    let t0 = std::time::Instant::now();
    let celer = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig { tol, ..Default::default() });
    let t_celer = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let cd = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol, ..CdConfig::vanilla() });
    let t_cd = t0.elapsed().as_secs_f64();
    assert!(celer.result.converged && cd.converged);
    assert!(
        t_celer < t_cd,
        "celer ({t_celer:.3}s) must beat vanilla CD ({t_cd:.3}s) at λ_max/20 on p=7129"
    );
}

#[test]
fn tiny_problems_and_lambda_max_edge() {
    let x = celer::data::DesignMatrix::Dense(celer::data::DenseMatrix::from_col_major(
        3,
        1,
        vec![1.0, 0.0, 0.0],
    ));
    let y = vec![2.0, 1.0, 0.0];
    let lmax = dual::lambda_max(&x, &y);
    assert_eq!(lmax, 2.0);
    let out = celer_solve_on(&x, &y, 1.0, None, &CelerConfig { tol: 1e-12, ..Default::default() });
    assert!((out.result.beta[0] - 1.0).abs() < 1e-10, "ST(2,1)=1");
    let out2 = celer_solve_on(&x, &y, 2.5, None, &CelerConfig::default());
    assert_eq!(out2.support_size(), 0);
}

#[test]
fn blitz_outer_gaps_monotone() {
    let ds = synth::leukemia_mini(105);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 15.0;
    let out = blitz_solve(&ds.x, &ds.y, lambda, None, &BlitzConfig { tol: 1e-8, ..Default::default() });
    let gaps: Vec<f64> = out.iterations.iter().map(|i| i.gap).collect();
    for w in gaps.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-9), "blitz outer gaps non-increasing: {gaps:?}");
    }
}

#[test]
fn deterministic_across_runs() {
    let ds = synth::finance_mini(107);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 7.0;
    let a = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig::default());
    let b = celer_solve_on(&ds.x, &ds.y, lambda, None, &CelerConfig::default());
    assert_eq!(a.result.beta, b.result.beta);
    assert_eq!(a.result.epochs, b.result.epochs);
}

#[test]
fn glmnet_false_positive_mechanism() {
    // Fig. 5 mechanism at a single λ: at loose primal-decrease tolerance,
    // GLMNET's support is a superset of (or equal to) the tight one.
    let ds = synth::leukemia_mini(108);
    let lmax = dual::lambda_max(&ds.x, &ds.y);
    let lambda = lmax / 20.0;
    let loose = glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-3, ..Default::default() });
    let tight = glmnet_solve(&ds.x, &ds.y, lambda, lmax, None, &GlmnetConfig { tol: 1e-13, ..Default::default() });
    assert!(loose.support_size() >= tight.support_size());
}
