//! Robustness-layer property tests (ISSUE 8).
//!
//! Always-on section: typed validation rejects every bad-input class on
//! both dense and CSC designs, the `try_*` front doors are bit-identical
//! to the plain solvers on valid input, and wall-clock budgets return
//! partial-but-certified state (finite gap, finite β, typed
//! `BudgetExhausted`) instead of garbage.
//!
//! `--features fault-inject` section: every injected fault ends in
//! `SolveOutcome::Recovered` (or a typed error) — never a NaN result —
//! and a recovered run is still gap-certified with an objective within
//! 2ε of a clean solve.

use celer::data::synth::{self, SynthDataset};
use celer::data::validate;
use celer::data::{CscMatrix, DenseMatrix, DesignMatrix, DesignOps};
use celer::lasso::{dual, primal};
use celer::solvers::batch::BatchConfig;
use celer::solvers::cd::{cd_solve, try_cd_solve, CdConfig};
use celer::solvers::celer::{celer_solve_on, try_celer_solve_on, CelerConfig};
use celer::solvers::engine::Workspace;
use celer::solvers::glm::{try_sparse_logreg_solve, try_sparse_poisson_solve};
use celer::solvers::path::{
    lambda_grid, run_path, run_path_batched, run_path_budgeted, try_lasso_path, try_run_path,
    PathSolver,
};
use celer::util::error::{SolveError, SolveOutcome};

fn problem() -> (SynthDataset, f64) {
    let ds = synth::leukemia_mini(7);
    let lambda = dual::lambda_max(&ds.x, &ds.y) * 0.1;
    (ds, lambda)
}

/// Densify → sparsify, so every property also runs on the CSC kernels.
fn sparsify(x: &DesignMatrix) -> DesignMatrix {
    let (n, p) = (x.n(), x.p());
    let mut buf = Vec::new();
    x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut buf);
    DesignMatrix::Sparse(CscMatrix::from_dense(n, p, &buf))
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Both storage layouts of the same 2×3 design.
fn both_layouts(data: &[f64]) -> [DesignMatrix; 2] {
    [
        DesignMatrix::Dense(DenseMatrix::from_col_major(2, 3, data.to_vec())),
        DesignMatrix::Sparse(CscMatrix::from_dense(2, 3, data)),
    ]
}

#[test]
fn validation_rejects_every_bad_input_class_on_dense_and_csc() {
    let clean = [1.0, -0.5, 2.0, 0.25, 0.0, -1.0];
    let y = [0.5, -0.25];
    let cfg = CdConfig::default();

    // Non-finite design entry, located by (row, col).
    let mut poisoned = clean;
    poisoned[3] = f64::NAN; // col-major, n = 2 → column 1, row 1
    for x in both_layouts(&poisoned) {
        assert!(matches!(
            try_cd_solve(&x, &y, 0.1, None, &cfg),
            Err(SolveError::NonFiniteDesign { row: 1, col: 1, .. })
        ));
    }

    for x in both_layouts(&clean) {
        // Non-finite label.
        assert!(matches!(
            try_cd_solve(&x, &[0.5, f64::NEG_INFINITY], 0.1, None, &cfg),
            Err(SolveError::NonFiniteLabels { index: 1, .. })
        ));
        // Row-count / label-count mismatch.
        assert!(matches!(
            try_cd_solve(&x, &[1.0, 2.0, 3.0], 0.1, None, &cfg),
            Err(SolveError::DimensionMismatch { rows: 2, labels: 3 })
        ));
        // Bad λ, on both the CD and the CELER front door.
        assert!(matches!(
            try_cd_solve(&x, &y, f64::NAN, None, &cfg),
            Err(SolveError::BadGrid { .. })
        ));
        assert!(matches!(
            try_celer_solve_on(&x, &y, -1.0, None, &CelerConfig::default()),
            Err(SolveError::BadGrid { .. })
        ));
        // Bad grid on the path front doors.
        let solver = PathSolver::by_name("celer", 1e-6).unwrap();
        assert!(matches!(
            try_run_path(&x, &y, &[1.0, f64::NAN], &solver, false),
            Err(SolveError::BadGrid { index: 1, .. })
        ));
        assert!(matches!(
            try_run_path(&x, &y, &[0.5, 1.0], &solver, false),
            Err(SolveError::BadGrid { index: 1, .. })
        ));
        // Bad tol on the batched-path front door.
        assert!(matches!(
            try_lasso_path(&x, &y, &[0.1], f64::NAN, 2, false, &celer::penalty::L1),
            Err(SolveError::BadConfig { .. })
        ));
    }

    // Penalty-weight domain (NaN / negative rejected; 0 and +inf legal).
    assert!(matches!(
        validate::validate_weights(&[1.0, -0.5]),
        Err(SolveError::BadWeight { index: 1, .. })
    ));
    assert!(validate::validate_weights(&[0.0, 1.0, f64::INFINITY]).is_ok());
}

#[test]
fn glm_label_domains_are_enforced_before_any_epoch() {
    let data = [1.0, -0.5, 2.0, 0.25, 0.0, -1.0];
    let cfg = CelerConfig::default();
    for x in both_layouts(&data) {
        // Logistic wants ±1 labels.
        assert!(matches!(
            try_sparse_logreg_solve(&x, &[1.0, 0.5], 0.1, None, &cfg),
            Err(SolveError::LabelDomain { family: "logistic", index: 1, .. })
        ));
        assert!(try_sparse_logreg_solve(&x, &[1.0, -1.0], 0.1, None, &cfg).is_ok());
        // Poisson wants finite counts ≥ 0.
        assert!(matches!(
            try_sparse_poisson_solve(&x, &[2.0, -1.0], 0.1, None, &cfg),
            Err(SolveError::LabelDomain { family: "poisson", index: 1, .. })
        ));
        assert!(try_sparse_poisson_solve(&x, &[2.0, 0.0], 0.1, None, &cfg).is_ok());
    }
}

#[test]
fn try_front_doors_are_bit_identical_to_plain_solvers() {
    let (ds, lambda) = problem();
    for x in [ds.x.clone(), sparsify(&ds.x)] {
        let cfg = CdConfig { tol: 1e-8, ..Default::default() };
        let plain = cd_solve(&x, &ds.y, lambda, None, &cfg);
        let tried = try_cd_solve(&x, &ds.y, lambda, None, &cfg).unwrap();
        assert_eq!(bits(&plain.beta), bits(&tried.beta));
        assert_eq!(plain.gap.to_bits(), tried.gap.to_bits());
        assert!(matches!(tried.status, SolveOutcome::Certified));

        let cc = CelerConfig { tol: 1e-8, ..Default::default() };
        let plain = celer_solve_on(&x, &ds.y, lambda, None, &cc);
        let tried = try_celer_solve_on(&x, &ds.y, lambda, None, &cc).unwrap();
        assert_eq!(bits(&plain.result.beta), bits(&tried.result.beta));
        assert_eq!(plain.result.gap.to_bits(), tried.result.gap.to_bits());
        assert!(matches!(tried.result.status, SolveOutcome::Certified));
    }
}

#[test]
fn zero_budget_returns_partial_but_certified_state() {
    let (ds, lambda) = problem();
    // Unreachable tol forces the budget (not convergence) to end the run;
    // the budget is checked right after a fresh gap evaluation, so the
    // returned state carries a finite certificate.
    let cfg = CdConfig { tol: 1e-16, max_seconds: Some(0.0), ..Default::default() };
    let res = cd_solve(&ds.x, &ds.y, lambda, None, &cfg);
    assert!(!res.converged);
    assert!(res.gap.is_finite());
    assert!(res.beta.iter().all(|v| v.is_finite()));
    assert!(matches!(res.status, SolveOutcome::BudgetExhausted { .. }));

    let cc = CelerConfig { tol: 1e-16, max_seconds: Some(0.0), ..Default::default() };
    let out = celer_solve_on(&ds.x, &ds.y, lambda, None, &cc);
    assert!(out.result.gap.is_finite());
    assert!(out.result.beta.iter().all(|v| v.is_finite()));
    assert!(matches!(out.result.status, SolveOutcome::BudgetExhausted { .. }));
}

#[test]
fn path_budget_truncates_grid_without_degrading_certificates() {
    let (ds, _) = problem();
    let grid = lambda_grid(dual::lambda_max(&ds.x, &ds.y), 0.1, 5);

    // Sequential path: an already-expired budget skips every grid point.
    let solver = PathSolver::CelerPrune(CelerConfig { tol: 1e-6, ..Default::default() });
    let mut ws = Workspace::new();
    let res = run_path_budgeted(&ds.x, &ds.y, &grid, &solver, false, Some(0.0), &mut ws);
    assert!(res.steps.is_empty());

    // Batched path: expired lanes retire unconverged with the trivial +∞
    // certificate — never NaN, never falsely Certified.
    let cfg = BatchConfig { tol: 1e-12, lanes: 2, max_seconds: Some(0.0), ..Default::default() };
    let res = run_path_batched(&ds.x, &ds.y, &grid, &cfg, false, &mut Workspace::new());
    assert!(res.steps.len() <= grid.len());
    for s in &res.steps {
        assert!(!s.gap.is_nan());
        assert!(
            s.converged || matches!(s.status, SolveOutcome::BudgetExhausted { .. }),
            "unconverged step must carry a typed budget outcome: {:?}",
            s.status
        );
    }
}

#[test]
fn clean_path_is_fully_certified() {
    let (ds, _) = problem();
    let grid = lambda_grid(dual::lambda_max(&ds.x, &ds.y), 0.1, 5);
    let solver = PathSolver::by_name("celer", 1e-6).unwrap();
    let res = run_path(&ds.x, &ds.y, &grid, &solver, false);
    assert!(res.all_converged());
    assert!(matches!(res.status(), SolveOutcome::Certified));
}

#[cfg(feature = "fault-inject")]
mod injected {
    use super::*;
    use celer::solvers::Precision;
    use celer::util::error::{FaultKind, RecoveryAction};
    use celer::util::fault::FaultPlan;

    #[test]
    fn injected_nan_residual_recovers_and_still_certifies() {
        let (ds, lambda) = problem();
        let tol = 1e-8;
        let clean = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol, ..Default::default() });
        assert!(clean.converged);

        let faults = FaultPlan::armed();
        faults.arm_nan_residual(2);
        let cfg = CdConfig { tol, faults, ..Default::default() };
        let hurt = cd_solve(&ds.x, &ds.y, lambda, None, &cfg);
        assert!(hurt.converged, "watchdog must roll back and re-certify");
        assert!(hurt.gap <= tol);
        assert!(hurt.beta.iter().all(|v| v.is_finite()));
        match &hurt.status {
            SolveOutcome::Recovered { faults } => {
                assert!(!faults.is_empty());
                assert!(faults.iter().all(|e| e.action == RecoveryAction::RolledBack));
                assert!(faults.iter().all(|e| matches!(
                    e.kind,
                    FaultKind::NonFiniteGap
                        | FaultKind::NonFiniteResidual
                        | FaultKind::NonFiniteDual
                )));
            }
            other => panic!("expected Recovered, got {other:?}"),
        }
        // A recovered-and-converged run is as good as a clean one: both
        // gaps ≤ ε bounds both objectives within ε of the optimum.
        let p_clean = primal::primal(&ds.x, &ds.y, &clean.beta, lambda);
        let p_hurt = primal::primal(&ds.x, &ds.y, &hurt.beta, lambda);
        assert!((p_clean - p_hurt).abs() <= 2.0 * tol, "{p_clean} vs {p_hurt}");
    }

    #[test]
    fn armed_but_silent_plan_is_bit_identical_to_inert() {
        let (ds, lambda) = problem();
        let base =
            cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol: 1e-8, ..Default::default() });
        let cfg = CdConfig { tol: 1e-8, faults: FaultPlan::armed(), ..Default::default() };
        let armed = cd_solve(&ds.x, &ds.y, lambda, None, &cfg);
        assert_eq!(bits(&base.beta), bits(&armed.beta));
        assert_eq!(base.gap.to_bits(), armed.gap.to_bits());
        assert!(matches!(armed.status, SolveOutcome::Certified));
    }

    #[test]
    fn f32_sweep_escalates_to_f64_on_injected_fault() {
        let (ds, lambda) = problem();
        let tol = 1e-8;
        let faults = FaultPlan::armed();
        faults.arm_nan_residual(1);
        let cfg = CdConfig { tol, precision: Precision::F32, faults, ..Default::default() };
        let res = cd_solve(&ds.x, &ds.y, lambda, None, &cfg);
        assert!(res.converged);
        assert!(res.gap <= tol);
        assert!(res.beta.iter().all(|v| v.is_finite()));
        assert!(
            res.status.faults().iter().any(|e| e.action == RecoveryAction::EscalatedF64),
            "f32 strategy must escalate to f64 on rollback: {:?}",
            res.status
        );
    }

    #[test]
    fn batched_path_restarts_injected_lane_and_matches_clean_objectives() {
        let (ds, _) = problem();
        let grid = lambda_grid(dual::lambda_max(&ds.x, &ds.y), 0.1, 5);
        let tol = 1e-8;
        let clean_cfg = BatchConfig { tol, lanes: 2, ..Default::default() };
        let clean = run_path_batched(&ds.x, &ds.y, &grid, &clean_cfg, true, &mut Workspace::new());
        assert!(clean.all_converged());

        let faults = FaultPlan::armed();
        faults.arm_nan_residual(1);
        let cfg = BatchConfig { tol, lanes: 2, faults, ..Default::default() };
        let hurt = run_path_batched(&ds.x, &ds.y, &grid, &cfg, true, &mut Workspace::new());
        assert!(hurt.all_converged(), "restarted lane must still converge");
        assert_eq!(hurt.steps.len(), grid.len());
        assert!(
            hurt.steps.iter().any(|s| matches!(s.status, SolveOutcome::Recovered { .. })),
            "exactly one lane took the one-shot fault"
        );
        for (h, c) in hurt.steps.iter().zip(clean.steps.iter()) {
            assert!(h.gap <= tol);
            let hb = h.beta.as_ref().unwrap();
            assert!(hb.iter().all(|v| v.is_finite()));
            let ph = primal::primal(&ds.x, &ds.y, hb, h.lambda);
            let pc = primal::primal(&ds.x, &ds.y, c.beta.as_ref().unwrap(), c.lambda);
            assert!((ph - pc).abs() <= 2.0 * tol, "λ = {}: {ph} vs {pc}", h.lambda);
        }
    }
}
