//! Property-based invariants over randomized problem instances.
//!
//! Offline build: no proptest crate — cases are generated with the
//! in-tree deterministic RNG (`celer::util::rng::Rng`), which gives the
//! same shrink-free but fully reproducible sweep on every run. Each
//! property runs dozens of randomized trials across shapes, densities,
//! seeds and λ ratios.

use celer::data::csc::CscMatrix;
use celer::data::dense::DenseMatrix;
use celer::data::design::{DesignMatrix, DesignOps};
use celer::lasso::{dual, primal};
use celer::solvers::cd::{cd_solve, CdConfig};
use celer::solvers::celer::{celer_solve_on, CelerConfig};
use celer::util::rng::Rng;
use celer::util::soft_threshold;

/// Random dense problem with unit-norm columns and standardized y.
fn random_problem(rng: &mut Rng, n: usize, p: usize, density: f64) -> (DesignMatrix, Vec<f64>) {
    let mut data = vec![0.0; n * p];
    for v in data.iter_mut() {
        if rng.uniform() < density {
            *v = rng.normal();
        }
    }
    // normalize columns
    for j in 0..p {
        let nrm: f64 = data[j * n..(j + 1) * n].iter().map(|v| v * v).sum::<f64>().sqrt();
        if nrm > 0.0 {
            for v in data[j * n..(j + 1) * n].iter_mut() {
                *v /= nrm;
            }
        }
    }
    let x = if density < 0.6 {
        DesignMatrix::Sparse(CscMatrix::from_dense(n, p, &data))
    } else {
        DesignMatrix::Dense(DenseMatrix::from_col_major(n, p, data))
    };
    let mut y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let ynorm = celer::util::linalg::norm(&y);
    for v in y.iter_mut() {
        *v /= ynorm;
    }
    (x, y)
}

#[test]
fn prop_gap_nonnegative_for_feasible_duals() {
    let mut rng = Rng::new(200);
    for trial in 0..40 {
        let n = 5 + rng.below(30);
        let p = 5 + rng.below(60);
        let density = 0.3 + rng.uniform() * 0.7;
        let (x, y) = random_problem(&mut rng, n, p, density);
        let lmax = dual::lambda_max(&x, &y);
        if lmax <= 0.0 {
            continue;
        }
        let lambda = lmax * (0.05 + 0.9 * rng.uniform());
        // random beta + rescaled-residual dual point
        let beta: Vec<f64> = (0..p).map(|_| if rng.uniform() < 0.2 { rng.normal() } else { 0.0 }).collect();
        let mut r = vec![0.0; n];
        primal::residual(&x, &y, &beta, &mut r);
        let theta = dual::rescale_to_feasible(&x, &r, lambda);
        assert!(dual::is_feasible(&x, &theta, 1e-10), "trial {trial}");
        let gap = dual::gap_from_residual(&r, &beta, &theta, &y, lambda);
        assert!(gap >= -1e-10, "trial {trial}: weak duality violated, gap {gap}");
    }
}

#[test]
fn prop_solver_gap_certificate_is_valid() {
    // the gap reported by the solver upper-bounds true suboptimality
    let mut rng = Rng::new(201);
    for trial in 0..12 {
        let n = 10 + rng.below(30);
        let p = 20 + rng.below(100);
        let (x, y) = random_problem(&mut rng, n, p, 1.0);
        let lmax = dual::lambda_max(&x, &y);
        let lambda = lmax * (0.1 + 0.4 * rng.uniform());
        let out = cd_solve(&x, &y, lambda, None, &CdConfig { tol: 1e-7, ..Default::default() });
        assert!(out.converged, "trial {trial}");
        // independent recomputation of the certificate
        let p_val = primal::primal(&x, &y, &out.beta, lambda);
        let d_val = dual::dual_objective(&y, &out.theta, lambda);
        assert!(dual::is_feasible(&x, &out.theta, 1e-9));
        assert!((p_val - d_val) <= 1e-7 * 1.001, "trial {trial}: {}", p_val - d_val);
    }
}

#[test]
fn prop_celer_matches_cd() {
    let mut rng = Rng::new(202);
    for trial in 0..10 {
        let n = 10 + rng.below(40);
        let p = 30 + rng.below(150);
        let (x, y) = random_problem(&mut rng, n, p, if trial % 2 == 0 { 1.0 } else { 0.3 });
        let lmax = dual::lambda_max(&x, &y);
        let lambda = lmax * (0.05 + 0.3 * rng.uniform());
        let a = celer_solve_on(&x, &y, lambda, None, &CelerConfig { tol: 1e-9, ..Default::default() });
        let b = cd_solve(&x, &y, lambda, None, &CdConfig { tol: 1e-10, ..Default::default() });
        assert!(a.result.converged, "trial {trial}");
        let pa = primal::primal(&x, &y, &a.result.beta, lambda);
        let pb = primal::primal(&x, &y, &b.beta, lambda);
        assert!(pa - pb < 1e-7, "trial {trial}: celer {pa} vs cd {pb}");
    }
}

#[test]
fn prop_screening_never_kills_support() {
    // restricted to n > p: the objective is strictly convex there, so the
    // solution (and its support) is unique and the property is well-posed.
    // With n < p the Lasso can have multiple optima with different
    // supports, and a screened run may legitimately land on another one.
    let mut rng = Rng::new(203);
    for trial in 0..10 {
        let p = 10 + rng.below(25);
        let n = p + 5 + rng.below(30);
        let (x, y) = random_problem(&mut rng, n, p, 1.0);
        let lmax = dual::lambda_max(&x, &y);
        let lambda = lmax * (0.1 + 0.5 * rng.uniform());
        let tight = cd_solve(&x, &y, lambda, None, &CdConfig { tol: 1e-13, max_epochs: 100_000, ..Default::default() });
        let screened = cd_solve(&x, &y, lambda, None, &CdConfig { tol: 1e-11, screen: true, ..Default::default() });
        for j in 0..p {
            if tight.beta[j].abs() > 1e-6 {
                assert!(
                    screened.beta[j] != 0.0,
                    "trial {trial}: support feature {j} lost (β̂={})",
                    tight.beta[j]
                );
            }
        }
    }
}

#[test]
fn prop_soft_threshold_is_prox() {
    // ST(x,u) = argmin_z ½(z−x)² + u|z| — verify variational inequality
    let mut rng = Rng::new(204);
    for _ in 0..1000 {
        let x = rng.normal() * 3.0;
        let u = rng.uniform() * 2.0;
        let z = soft_threshold(x, u);
        let obj = |t: f64| 0.5 * (t - x) * (t - x) + u * t.abs();
        for dt in [-0.1, -1e-3, 1e-3, 0.1] {
            assert!(obj(z) <= obj(z + dt) + 1e-12, "x={x} u={u} z={z} dt={dt}");
        }
    }
}

#[test]
fn prop_csc_dense_duality() {
    // every DesignOps op agrees between storages on random matrices
    let mut rng = Rng::new(205);
    for _ in 0..25 {
        let n = 1 + rng.below(20);
        let p = 1 + rng.below(30);
        let mut data = vec![0.0; n * p];
        for v in data.iter_mut() {
            if rng.uniform() < 0.4 {
                *v = rng.normal();
            }
        }
        let d = DenseMatrix::from_col_major(n, p, data.clone());
        let s = CscMatrix::from_dense(n, p, &data);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let beta: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        for j in 0..p {
            assert!((d.col_dot(j, &v) - s.col_dot(j, &v)).abs() < 1e-12);
            assert_eq!(d.col_nnz(j), s.col_nnz(j));
        }
        let (mut a, mut b) = (vec![0.0; n], vec![0.0; n]);
        d.matvec(&beta, &mut a);
        s.matvec(&beta, &mut b);
        for i in 0..n {
            assert!((a[i] - b[i]).abs() < 1e-12);
        }
        assert!((d.xt_abs_max(&v) - s.xt_abs_max(&v)).abs() < 1e-12);
    }
}

#[test]
fn prop_lambda_max_is_tight() {
    // β̂ = 0 exactly at λ ≥ λ_max, and ≠ 0 just below
    let mut rng = Rng::new(206);
    for trial in 0..10 {
        let n = 8 + rng.below(20);
        let p = 8 + rng.below(40);
        let (x, y) = random_problem(&mut rng, n, p, 1.0);
        let lmax = dual::lambda_max(&x, &y);
        let at = cd_solve(&x, &y, lmax * 1.000001, None, &CdConfig { tol: 1e-12, ..Default::default() });
        assert_eq!(at.support_size(), 0, "trial {trial}: nonzero β at λ≥λ_max");
        let below = cd_solve(&x, &y, lmax * 0.95, None, &CdConfig { tol: 1e-12, ..Default::default() });
        assert!(below.support_size() > 0, "trial {trial}: zero β at 0.95·λ_max");
    }
}

#[test]
fn prop_extrapolated_dual_never_worse_with_best_dual() {
    // with Eq. 13 monotonicity, the solver's dual objective sequence is
    // non-decreasing along checks
    let mut rng = Rng::new(207);
    for trial in 0..8 {
        let n = 10 + rng.below(30);
        let p = 30 + rng.below(100);
        let (x, y) = random_problem(&mut rng, n, p, 1.0);
        let lmax = dual::lambda_max(&x, &y);
        let lambda = lmax * 0.1;
        let out = cd_solve(
            &x,
            &y,
            lambda,
            None,
            &CdConfig { tol: 1e-12, max_epochs: 500, trace: true, best_dual: true, ..Default::default() },
        );
        let duals: Vec<f64> = out
            .trace
            .iter()
            .map(|c| c.primal - c.gap) // D(θ_used) = P − gap
            .collect();
        for w in duals.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "trial {trial}: dual decreased {w:?}");
        }
    }
}
