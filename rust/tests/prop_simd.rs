//! Property tests for the vectorized kernel layer (`util::simd`) and the
//! f32 sweep mode (`Precision::F32`).
//!
//! The f64 pins are **bitwise**: every reduction the solver performs is
//! asserted equal, bit for bit, to a test-local scalar reference that
//! re-implements the documented accumulator-order contract (element `i`
//! into `acc[i % W]`, fixed pairwise lane reduction — see `util::simd`).
//! The references here are deliberately written the slow, obvious way so
//! a regression in the kernel layer cannot hide behind a matching
//! "optimization" in the test.

use celer::data::csc::CscMatrix;
use celer::data::dense::DenseMatrix;
use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::synth;
use celer::data::view::DesignView;
use celer::lasso::{dual, primal};
use celer::solvers::batch::{
    solve_grid, BatchCdStrategy, BatchConfig, BatchF32Strategy, BatchWorkspace,
};
use celer::solvers::cd::{cd_solve, CdConfig};
use celer::solvers::path::lambda_grid;
use celer::solvers::Precision;
use celer::util::linalg;
use celer::util::par;
use celer::util::rng::Rng;

// ---------------------------------------------------------------------
// Test-local scalar references for the reduction contract.
// ---------------------------------------------------------------------

/// Width-8 contract: element `i` into `acc[i % 8]`, pairwise tree.
fn ref_fold8<F: Fn(usize) -> f64>(len: usize, f: F) -> f64 {
    let mut acc = [0.0f64; 8];
    for i in 0..len {
        acc[i % 8] += f(i);
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Width-4 gather contract: entry `k` into `acc[k % 4]`, pairwise tree.
fn ref_fold4<F: Fn(usize) -> f64>(len: usize, f: F) -> f64 {
    let mut acc = [0.0f64; 4];
    for i in 0..len {
        acc[i % 4] += f(i);
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// `dot` under the contract (reference for every contiguous f64 dot).
fn ref_dot(a: &[f64], b: &[f64]) -> f64 {
    ref_fold8(a.len(), |i| a[i] * b[i])
}

/// Odd lengths around every chunk boundary, plus degenerate cases.
const LENS: [usize; 16] = [0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 257];

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// A dense/CSC pair over the same values (with genuine zeros so the CSC
/// entry arrays exercise odd lengths).
fn design_pair(seed: u64, n: usize, p: usize) -> (DenseMatrix, CscMatrix) {
    let mut rng = Rng::new(seed);
    let mut data = vec![0.0; n * p];
    for v in data.iter_mut() {
        if rng.uniform() < 0.6 {
            *v = rng.normal();
        }
    }
    (DenseMatrix::from_col_major(n, p, data.clone()), CscMatrix::from_dense(n, p, &data))
}

// ---------------------------------------------------------------------
// f64 bitwise identity: linalg and design kernels vs. the contract.
// ---------------------------------------------------------------------

#[test]
fn linalg_reductions_follow_the_contract_bitwise() {
    let mut rng = Rng::new(11);
    for &n in &LENS {
        let a = rand_vec(&mut rng, n);
        let b = rand_vec(&mut rng, n);
        assert_eq!(linalg::dot(&a, &b).to_bits(), ref_dot(&a, &b).to_bits(), "dot n={n}");
        let asum_ref = ref_fold8(n, |i| a[i].abs());
        assert_eq!(linalg::asum(&a).to_bits(), asum_ref.to_bits(), "asum n={n}");
        assert_eq!(
            linalg::nrm2(&a).to_bits(),
            ref_fold8(n, |i| a[i] * a[i]).sqrt().to_bits(),
            "nrm2 n={n}"
        );
        assert_eq!(
            primal::l1_norm(&a).to_bits(),
            ref_fold8(n, |i| a[i].abs()).to_bits(),
            "l1_norm n={n}"
        );
    }
}

#[test]
fn dense_design_kernels_follow_the_contract_bitwise() {
    let mut rng = Rng::new(12);
    for &n in &[1usize, 5, 8, 31, 257] {
        let (dense, _) = design_pair(100 + n as u64, n, 4);
        let v = rand_vec(&mut rng, n);
        let w: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        for j in 0..4 {
            let mut col = vec![0.0; n];
            let mut buf = Vec::new();
            dense.gather_dense(&[j], &mut buf);
            col.copy_from_slice(&buf);
            assert_eq!(
                dense.col_dot(j, &v).to_bits(),
                ref_dot(&col, &v).to_bits(),
                "col_dot n={n} j={j}"
            );
            assert_eq!(
                dense.col_norm_sq(j).to_bits(),
                ref_fold8(n, |i| col[i] * col[i]).to_bits(),
                "col_norm_sq n={n} j={j}"
            );
            assert_eq!(
                dense.col_wnorm_sq(j, &w).to_bits(),
                ref_fold8(n, |i| w[i] * col[i] * col[i]).to_bits(),
                "col_wnorm_sq n={n} j={j}"
            );
            // element-wise kernels: exactly the naive per-element update
            let mut out = v.clone();
            dense.col_axpy(j, -0.75, &mut out);
            let naive: Vec<f64> = (0..n).map(|i| v[i] + -0.75 * col[i]).collect();
            assert_eq!(out, naive, "col_axpy n={n} j={j}");
            let mut out = v.clone();
            dense.col_waxpy(j, 0.5, &w, &mut out);
            let naive: Vec<f64> = (0..n).map(|i| v[i] + 0.5 * w[i] * col[i]).collect();
            assert_eq!(out, naive, "col_waxpy n={n} j={j}");
        }
    }
}

#[test]
fn csc_design_kernels_follow_the_gather_contract_bitwise() {
    let mut rng = Rng::new(13);
    for &n in &[1usize, 7, 29, 64, 130] {
        let (_, csc) = design_pair(200 + n as u64, n, 5);
        let v = rand_vec(&mut rng, n);
        let w: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        for j in 0..5 {
            let (idx, val) = csc.col(j);
            let m = idx.len();
            assert_eq!(
                csc.col_dot(j, &v).to_bits(),
                ref_fold4(m, |k| val[k] * v[idx[k] as usize]).to_bits(),
                "csc col_dot n={n} j={j}"
            );
            // col_norm_sq routes the contiguous value array through the
            // width-8 contract (no gather needed).
            assert_eq!(
                csc.col_norm_sq(j).to_bits(),
                ref_fold8(m, |k| val[k] * val[k]).to_bits(),
                "csc col_norm_sq n={n} j={j}"
            );
            assert_eq!(
                csc.col_wnorm_sq(j, &w).to_bits(),
                ref_fold4(m, |k| w[idx[k] as usize] * val[k] * val[k]).to_bits(),
                "csc col_wnorm_sq n={n} j={j}"
            );
            // scatters: one add per stored entry, same as the naive loop
            let mut out = v.clone();
            csc.col_axpy(j, 1.25, &mut out);
            let mut naive = v.clone();
            for k in 0..m {
                naive[idx[k] as usize] += 1.25 * val[k];
            }
            assert_eq!(out, naive, "csc col_axpy n={n} j={j}");
        }
    }
}

#[test]
fn view_kernels_are_bitwise_parent_kernels() {
    let (dense, csc) = design_pair(42, 57, 12);
    let cols = vec![1usize, 4, 7, 11];
    let mut rng = Rng::new(14);
    let v = rand_vec(&mut rng, 57);
    let dn = dense.col_norms_sq();
    let view_d = DesignView::new(&dense, &cols, &dn);
    let sn = csc.col_norms_sq();
    let view_s = DesignView::new(&csc, &cols, &sn);
    for (t, &j) in cols.iter().enumerate() {
        assert_eq!(view_d.col_dot(t, &v).to_bits(), dense.col_dot(j, &v).to_bits(), "dense t={t}");
        assert_eq!(view_s.col_dot(t, &v).to_bits(), csc.col_dot(j, &v).to_bits(), "csc t={t}");
        assert_eq!(view_d.col_norm_sq(t).to_bits(), dense.col_norm_sq(j).to_bits());
        assert_eq!(view_s.col_norm_sq(t).to_bits(), csc.col_norm_sq(j).to_bits());
    }
}

// ---------------------------------------------------------------------
// Lane kernels: the cache-blocked / entry-pair batched contracts.
// ---------------------------------------------------------------------

#[test]
fn dense_lane_kernels_follow_the_blocked_contract_bitwise() {
    // col_dot_lanes processes the column in 256-row blocks, each block
    // reduced under the width-8 contract, blocks accumulated in order.
    const BLOCK: usize = 256;
    for &n in &[5usize, 255, 256, 257, 600] {
        let (dense, _) = design_pair(300 + n as u64, n, 3);
        let lanes = [0usize, 2, 3];
        let mut rng = Rng::new(15);
        let v = rand_vec(&mut rng, 4 * n);
        let mut buf = Vec::new();
        for j in 0..3 {
            dense.gather_dense(&[j], &mut buf);
            let col = buf.clone();
            let mut got = vec![0.0; lanes.len()];
            dense.col_dot_lanes(j, &v, n, &lanes, &mut got);
            for (t, &k) in lanes.iter().enumerate() {
                let mut expect = 0.0;
                let mut i = 0;
                while i < n {
                    let hi = (i + BLOCK).min(n);
                    expect += ref_dot(&col[i..hi], &v[k * n + i..k * n + hi]);
                    i = hi;
                }
                assert_eq!(got[t].to_bits(), expect.to_bits(), "n={n} j={j} lane={k}");
            }
            // col_axpy_lanes is element-wise: bitwise the per-lane naive update
            let alphas = [0.5, 0.0, -1.25];
            let mut batched = v.clone();
            dense.col_axpy_lanes(j, &alphas, &mut batched, n, &lanes);
            let mut naive = v.clone();
            for (t, &k) in lanes.iter().enumerate() {
                for i in 0..n {
                    naive[k * n + i] += alphas[t] * col[i];
                }
            }
            assert_eq!(batched, naive, "axpy_lanes n={n} j={j}");
        }
    }
}

#[test]
fn csc_lane_kernels_follow_the_entry_pair_contract_bitwise() {
    // col_dot_lanes decodes each stored entry once and accumulates
    // entry PAIRS per lane (odd tail entry alone).
    for &n in &[6usize, 33, 101] {
        let (_, csc) = design_pair(400 + n as u64, n, 4);
        let lanes = [0usize, 1, 3];
        let mut rng = Rng::new(16);
        let v: Vec<f64> = (0..4 * n).map(|_| rng.normal()).collect();
        for j in 0..4 {
            let (idx, val) = csc.col(j);
            let m = idx.len();
            let mut got = vec![0.0; lanes.len()];
            csc.col_dot_lanes(j, &v, n, &lanes, &mut got);
            for (t, &k) in lanes.iter().enumerate() {
                let base = k * n;
                let mut expect = 0.0;
                let main = m - m % 2;
                let mut e = 0;
                while e < main {
                    expect += val[e] * v[base + idx[e] as usize]
                        + val[e + 1] * v[base + idx[e + 1] as usize];
                    e += 2;
                }
                if main < m {
                    expect += val[main] * v[base + idx[main] as usize];
                }
                assert_eq!(got[t].to_bits(), expect.to_bits(), "n={n} j={j} lane={k}");
            }
            // scatter: one add per (entry, lane) — the per-lane naive loop
            let alphas = [2.0, -0.5, 0.25];
            let mut batched = v.clone();
            csc.col_axpy_lanes(j, &alphas, &mut batched, n, &lanes);
            let mut naive = v.clone();
            for (t, &k) in lanes.iter().enumerate() {
                csc.col_axpy(j, alphas[t], &mut naive[k * n..(k + 1) * n]);
            }
            assert_eq!(batched, naive, "csc axpy_lanes n={n} j={j}");
        }
    }
}

// ---------------------------------------------------------------------
// f32 sweep mode: f64-certified gaps, matching supports, invariance.
// ---------------------------------------------------------------------

#[test]
fn f32_mode_yields_f64_certified_gaps_and_matching_supports() {
    let ds = synth::leukemia_mini(31);
    let (n, p) = (ds.x.n(), ds.x.p());
    let mut buf = Vec::new();
    ds.x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut buf);
    let sparse = DesignMatrix::Sparse(CscMatrix::from_dense(n, p, &buf));
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 5.0;
    let tol = 1e-10;
    for x in [&ds.x, &sparse] {
        let f64_out = cd_solve(x, &ds.y, lambda, None, &CdConfig { tol, ..Default::default() });
        let f32_out = cd_solve(
            x,
            &ds.y,
            lambda,
            None,
            &CdConfig { tol, precision: Precision::F32, ..Default::default() },
        );
        assert!(f32_out.converged, "f32 mode converges below f32 resolution");
        assert!(f32_out.gap <= tol);
        // The certificate invariant: the returned residual is the exact
        // f64 residual of the returned β — nothing f32 leaks out.
        let mut r_exact = vec![0.0; n];
        primal::residual(x, &ds.y, &f32_out.beta, &mut r_exact);
        assert_eq!(f32_out.r, r_exact, "returned r is the exact f64 residual");
        // Both runs are gap-certified at ε ⇒ objectives within 2ε and
        // (at this ε, far below the coefficient scale) equal supports.
        let p32 = primal::primal(x, &ds.y, &f32_out.beta, lambda);
        let p64 = primal::primal(x, &ds.y, &f64_out.beta, lambda);
        assert!((p32 - p64).abs() <= 2.0 * tol, "{p32} vs {p64}");
        let support = |b: &[f64]| -> Vec<usize> {
            b.iter().enumerate().filter(|(_, v)| v.abs() > 1e-6).map(|(j, _)| j).collect()
        };
        assert_eq!(support(&f32_out.beta), support(&f64_out.beta), "supports match");
    }
}

#[test]
fn f32_mode_is_thread_count_invariant() {
    // The f32 epochs are serial and the certification path reuses the
    // pooled-but-deterministic f64 kernels, so forcing the serial
    // runtime must reproduce the pooled run bit for bit.
    let ds = synth::leukemia_mini(32);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
    let cfg = CdConfig { tol: 1e-8, precision: Precision::F32, screen: true, ..Default::default() };
    let pooled = cd_solve(&ds.x, &ds.y, lambda, None, &cfg);
    let serial = par::run_serial(|| cd_solve(&ds.x, &ds.y, lambda, None, &cfg));
    assert_eq!(pooled.beta, serial.beta);
    assert_eq!(pooled.gap.to_bits(), serial.gap.to_bits());
    assert_eq!(pooled.epochs, serial.epochs);
}

#[test]
fn batched_f32_grid_is_certified_and_matches_f64() {
    let ds = synth::leukemia_mini(33);
    let lmax = dual::lambda_max(&ds.x, &ds.y);
    let grid = lambda_grid(lmax, 0.1, 6);
    let tol = 1e-9;
    let c64 = BatchConfig { tol, lanes: 3, ..Default::default() };
    let c32 = BatchConfig { precision: Precision::F32, ..c64.clone() };
    let mut ws64 = BatchWorkspace::new();
    let a = solve_grid(&ds.x, &ds.y, &grid, None, &c64, &mut ws64, &mut BatchCdStrategy);
    let mut ws32 = BatchWorkspace::new();
    let mut strat = BatchF32Strategy::new(&ds.x);
    let b = solve_grid(&ds.x, &ds.y, &grid, None, &c32, &mut ws32, &mut strat);
    assert_eq!(a.len(), b.len());
    for (la, lb) in a.iter().zip(&b) {
        assert!(lb.converged, "λ#{}", lb.grid_idx);
        assert!(lb.gap <= tol);
        let pa = primal::primal(&ds.x, &ds.y, &la.beta, la.lambda);
        let pb = primal::primal(&ds.x, &ds.y, &lb.beta, lb.lambda);
        assert!((pa - pb).abs() <= 2.0 * tol, "λ#{}: {pa} vs {pb}", la.grid_idx);
    }
}

// ---------------------------------------------------------------------
// Heavier f32 stress tier — run by the CI `--features f32-sweep` cell.
// ---------------------------------------------------------------------

#[cfg(feature = "f32-sweep")]
mod f32_stress {
    use super::*;

    /// A long warm-started path in f32 mode: every λ on a 30-point grid
    /// down to λmax/50 must come back f64-gap-certified, sequential and
    /// batched, dense and CSC.
    #[test]
    fn f32_long_path_is_certified_on_every_lambda() {
        let ds = synth::leukemia_mini(41);
        let (n, p) = (ds.x.n(), ds.x.p());
        let mut buf = Vec::new();
        ds.x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut buf);
        let sparse = DesignMatrix::Sparse(CscMatrix::from_dense(n, p, &buf));
        let tol = 1e-8;
        for x in [&ds.x, &sparse] {
            let lmax = dual::lambda_max(x, &ds.y);
            let grid = lambda_grid(lmax, 0.02, 30);
            // Sequential chain with warm starts, f32 sweeps per solve.
            let cfg = CdConfig { tol, precision: Precision::F32, ..Default::default() };
            let mut warm: Option<Vec<f64>> = None;
            for &lambda in &grid {
                let out = cd_solve(x, &ds.y, lambda, warm.as_deref(), &cfg);
                assert!(out.converged, "λ={lambda}");
                assert!(out.gap <= tol, "λ={lambda}: gap {}", out.gap);
                let mut r_exact = vec![0.0; n];
                primal::residual(x, &ds.y, &out.beta, &mut r_exact);
                assert_eq!(out.r, r_exact, "λ={lambda}: exact f64 residual");
                warm = Some(out.beta);
            }
            // Batched lanes over the same grid.
            let bc = BatchConfig { tol, lanes: 4, precision: Precision::F32, ..Default::default() };
            let mut ws = BatchWorkspace::new();
            let mut strat = BatchF32Strategy::new(x);
            let lanes = solve_grid(x, &ds.y, &grid, None, &bc, &mut ws, &mut strat);
            assert_eq!(lanes.len(), grid.len());
            for lane in &lanes {
                assert!(lane.converged && lane.gap <= tol, "λ#{}", lane.grid_idx);
            }
        }
    }

    /// f32 mode on a design whose columns span ~6 orders of magnitude in
    /// scale — the f32 fixed-point escalation must still hand every
    /// column to the f64 phase and certify.
    #[test]
    fn f32_mode_survives_badly_scaled_columns() {
        let mut rng = Rng::new(42);
        let (n, p) = (80usize, 40usize);
        let mut data = vec![0.0; n * p];
        for j in 0..p {
            let scale = 10f64.powi((j % 7) as i32 - 3); // 1e-3 … 1e3
            for i in 0..n {
                data[j * n + i] = scale * rng.normal();
            }
        }
        let x = DenseMatrix::from_col_major(n, p, data);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let lambda = dual::lambda_max(&x, &y) / 20.0;
        let tol = 1e-9;
        let out = cd_solve(
            &x,
            &y,
            lambda,
            None,
            &CdConfig { tol, precision: Precision::F32, ..Default::default() },
        );
        assert!(out.converged);
        assert!(out.gap <= tol);
        let mut r_exact = vec![0.0; n];
        primal::residual(&x, &y, &out.beta, &mut r_exact);
        assert_eq!(out.r, r_exact);
    }
}
