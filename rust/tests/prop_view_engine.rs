//! Property tests for the zero-copy data/solver refactor:
//!
//! 1. an inner solve on a [`DesignView`] of `X_W` is bit-identical
//!    (within 1e-12, in practice exactly equal) to the same solve on a
//!    `select_columns`-materialized copy, for dense AND sparse designs;
//! 2. warm-started λ-path results are unchanged by workspace reuse.

use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::synth;
use celer::data::view::DesignView;
use celer::lasso::dual;
use celer::solvers::cd::{cd_solve, CdConfig};
use celer::solvers::celer::{celer_solve_on, celer_solve_on_ws, CelerConfig};
use celer::solvers::engine::Workspace;
use celer::solvers::path::{lambda_grid, run_path, run_path_with_workspace, PathSolver};

/// Pick a deterministic pseudo-working-set: the `k` columns most
/// correlated with y, plus a few arbitrary ones.
fn pick_working_set(x: &DesignMatrix, y: &[f64], k: usize) -> Vec<usize> {
    let p = x.p();
    let mut xty = vec![0.0; p];
    x.xt_vec(y, &mut xty);
    let mut idx: Vec<usize> = (0..p).collect();
    idx.sort_by(|&a, &b| xty[b].abs().partial_cmp(&xty[a].abs()).unwrap());
    let mut ws: Vec<usize> = idx.into_iter().take(k).collect();
    ws.push(p - 1);
    ws.push(p / 2);
    ws.sort_unstable();
    ws.dedup();
    ws
}

fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert!(
            (a[i] - b[i]).abs() <= tol,
            "{what}[{i}]: {} vs {} (diff {})",
            a[i],
            b[i],
            (a[i] - b[i]).abs()
        );
    }
}

fn check_view_inner_solve_matches_materialized(x: &DesignMatrix, y: &[f64], seed_tag: &str) {
    let lambda = dual::lambda_max(x, y) / 10.0;
    let ws_cols = pick_working_set(x, y, 40);
    let cfg = CdConfig { tol: 1e-9, ..Default::default() };

    // Old path: materialize X_W and solve on the copy.
    let materialized = x.select_columns(&ws_cols);
    let a = cd_solve(&materialized, y, lambda, None, &cfg);

    // New path: zero-copy view over the parent, monomorphized per kind.
    let norms = x.col_norms_sq();
    let b = match x {
        DesignMatrix::Dense(d) => {
            let view = DesignView::new(d, &ws_cols, &norms);
            cd_solve(&view, y, lambda, None, &cfg)
        }
        DesignMatrix::Sparse(s) => {
            let view = DesignView::new(s, &ws_cols, &norms);
            cd_solve(&view, y, lambda, None, &cfg)
        }
        DesignMatrix::Ooc(o) => {
            let view = DesignView::new(o, &ws_cols, &norms);
            cd_solve(&view, y, lambda, None, &cfg)
        }
        DesignMatrix::Sharded(sh) => {
            let view = DesignView::new(sh, &ws_cols, &norms);
            cd_solve(&view, y, lambda, None, &cfg)
        }
    };

    assert_eq!(a.epochs, b.epochs, "{seed_tag}: epoch counts diverge");
    assert_eq!(a.converged, b.converged, "{seed_tag}: convergence diverges");
    assert_close(&a.beta, &b.beta, 1e-12, &format!("{seed_tag}: beta"));
    assert_close(&a.r, &b.r, 1e-12, &format!("{seed_tag}: residual"));
    assert_close(&a.theta, &b.theta, 1e-12, &format!("{seed_tag}: theta"));
    assert!((a.gap - b.gap).abs() <= 1e-12, "{seed_tag}: gap {} vs {}", a.gap, b.gap);
}

#[test]
fn view_inner_solve_matches_materialized_dense() {
    for seed in [101u64, 102, 103] {
        let ds = synth::leukemia_mini(seed);
        assert!(!ds.x.is_sparse());
        check_view_inner_solve_matches_materialized(&ds.x, &ds.y, &format!("dense/{seed}"));
    }
}

#[test]
fn view_inner_solve_matches_materialized_sparse() {
    for seed in [201u64, 202] {
        let ds = synth::finance_mini(seed);
        assert!(ds.x.is_sparse());
        check_view_inner_solve_matches_materialized(&ds.x, &ds.y, &format!("sparse/{seed}"));
    }
}

#[test]
fn view_warm_start_matches_materialized() {
    // Warm-started subproblem solves (CELER's actual usage) must agree too.
    let ds = synth::leukemia_mini(104);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 15.0;
    let ws_cols = pick_working_set(&ds.x, &ds.y, 60);
    let cfg = CdConfig { tol: 1e-10, ..Default::default() };
    let materialized = ds.x.select_columns(&ws_cols);
    let cold = cd_solve(&materialized, &ds.y, lambda, None, &cfg);
    let a = cd_solve(&materialized, &ds.y, lambda, Some(&cold.beta), &cfg);
    let norms = ds.x.col_norms_sq();
    let b = match &ds.x {
        DesignMatrix::Dense(d) => {
            let view = DesignView::new(d, &ws_cols, &norms);
            cd_solve(&view, &ds.y, lambda, Some(&cold.beta), &cfg)
        }
        DesignMatrix::Sparse(s) => {
            let view = DesignView::new(s, &ws_cols, &norms);
            cd_solve(&view, &ds.y, lambda, Some(&cold.beta), &cfg)
        }
        DesignMatrix::Ooc(o) => {
            let view = DesignView::new(o, &ws_cols, &norms);
            cd_solve(&view, &ds.y, lambda, Some(&cold.beta), &cfg)
        }
        DesignMatrix::Sharded(sh) => {
            let view = DesignView::new(sh, &ws_cols, &norms);
            cd_solve(&view, &ds.y, lambda, Some(&cold.beta), &cfg)
        }
    };
    assert_eq!(a.epochs, b.epochs);
    assert_close(&a.beta, &b.beta, 1e-12, "warm beta");
}

#[test]
fn workspace_reuse_leaves_path_unchanged() {
    // A warm-started path with one shared workspace must produce exactly
    // the same trajectory as fresh workspaces per λ.
    for (name, dense) in [("celer-prune", true), ("celer-safe", true), ("blitz", false)] {
        let ds = if dense { synth::leukemia_mini(105) } else { synth::finance_mini(106) };
        let lmax = dual::lambda_max(&ds.x, &ds.y);
        let grid = lambda_grid(lmax * 0.95, 0.05, 6);
        let solver = PathSolver::by_name(name, 1e-8).unwrap();

        let fresh = run_path(&ds.x, &ds.y, &grid, &solver, true);
        let mut ws = Workspace::new();
        let reused = run_path_with_workspace(&ds.x, &ds.y, &grid, &solver, true, &mut ws);

        assert_eq!(fresh.steps.len(), reused.steps.len(), "{name}");
        for (i, (a, b)) in fresh.steps.iter().zip(reused.steps.iter()).enumerate() {
            assert_eq!(a.converged, b.converged, "{name} step {i}");
            assert_eq!(a.epochs, b.epochs, "{name} step {i} epochs");
            assert_eq!(a.support_size, b.support_size, "{name} step {i} support");
            let (ba, bb) = (a.beta.as_ref().unwrap(), b.beta.as_ref().unwrap());
            assert_close(ba, bb, 1e-12, &format!("{name} step {i} beta"));
        }
    }
}

#[test]
fn celer_workspace_reuse_across_lambdas_matches_one_shot() {
    let ds = synth::leukemia_mini(107);
    let lmax = dual::lambda_max(&ds.x, &ds.y);
    let cfg = CelerConfig { tol: 1e-9, ..Default::default() };
    let mut ws = Workspace::new();
    let mut warm: Option<Vec<f64>> = None;
    for ratio in [3.0f64, 8.0, 20.0] {
        let lambda = lmax / ratio;
        let one_shot = celer_solve_on(&ds.x, &ds.y, lambda, warm.as_deref(), &cfg);
        let reused = celer_solve_on_ws(&ds.x, &ds.y, lambda, warm.as_deref(), &cfg, &mut ws);
        assert_close(
            &one_shot.result.beta,
            &reused.result.beta,
            1e-12,
            &format!("lambda ratio {ratio}"),
        );
        assert_eq!(one_shot.iterations.len(), reused.iterations.len());
        warm = Some(one_shot.result.beta);
    }
}
