//! Property tests on the coordinator substrate: bucket routing, padding
//! invariance, scheduler determinism, JSON/manifest round-trips.

use celer::coordinator::scheduler::run_parallel;
use celer::runtime::artifacts::ArtifactRegistry;
use celer::runtime::{Engine, NativeEngine};
use celer::util::json::{parse, Json};
use celer::util::rng::Rng;
use std::path::Path;

#[test]
fn prop_padding_invariance_native() {
    // inner_solve on (n, w) must equal inner_solve on the zero-padded
    // (n, w + pad) problem restricted to the first w coordinates — the
    // exact property the shape-bucket router relies on.
    let mut rng = Rng::new(300);
    for trial in 0..20 {
        let n = 4 + rng.below(24);
        let w = 1 + rng.below(20);
        let pad = rng.below(16);
        let mut x_cm = vec![0.0; n * w];
        for v in x_cm.iter_mut() {
            *v = rng.normal();
        }
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let beta0 = vec![0.0; w];
        let lambda = 0.3;
        let mut eng = NativeEngine;
        let (b_plain, r_plain) = eng.inner_solve(&x_cm, n, w, &y, &beta0, lambda).unwrap();
        let mut x_pad = x_cm.clone();
        x_pad.extend(std::iter::repeat(0.0).take(pad * n));
        let beta_pad = vec![0.0; w + pad];
        let (b_pad, r_pad) = eng.inner_solve(&x_pad, n, w + pad, &y, &beta_pad, lambda).unwrap();
        for j in 0..w {
            assert!((b_plain[j] - b_pad[j]).abs() < 1e-14, "trial {trial} j={j}");
        }
        for j in w..(w + pad) {
            assert_eq!(b_pad[j], 0.0, "trial {trial}: padded coef must stay 0");
        }
        for i in 0..n {
            assert!((r_plain[i] - r_pad[i]).abs() < 1e-14);
        }
    }
}

#[test]
fn prop_scores_padding_gets_sentinel() {
    let mut rng = Rng::new(301);
    let n = 12;
    let w = 6;
    let pad = 5;
    let mut x_cm = vec![0.0; n * w];
    for v in x_cm.iter_mut() {
        *v = rng.normal();
    }
    x_cm.extend(std::iter::repeat(0.0).take(pad * n));
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let beta = vec![0.0; w + pad];
    let theta: Vec<f64> = y.iter().map(|v| v * 0.05).collect();
    let mut eng = NativeEngine;
    let (_, _, _, d) = eng.gap_scores(&x_cm, n, w + pad, &y, &beta, &theta, 0.5).unwrap();
    for j in w..(w + pad) {
        assert_eq!(d[j], celer::runtime::EMPTY_COL_SCORE);
    }
}

#[test]
fn prop_scheduler_matches_serial_map() {
    let mut rng = Rng::new(302);
    for _ in 0..10 {
        let n = rng.below(200);
        let items: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1000).collect();
        let serial: Vec<u64> = items.iter().map(|&v| v * v + 1).collect();
        for workers in [1, 2, 3, 8] {
            let par = run_parallel(items.clone(), workers, |&v| v * v + 1);
            assert_eq!(par, serial, "workers={workers}");
        }
    }
}

#[test]
fn prop_manifest_bucket_router() {
    // random manifests: the chosen bucket is always the smallest fitting one
    let mut rng = Rng::new(303);
    for trial in 0..20 {
        let n = 16 + rng.below(3) * 16;
        let mut widths: Vec<usize> = (0..(1 + rng.below(5))).map(|i| 32 << i).collect();
        widths.dedup();
        let arts: Vec<String> = widths
            .iter()
            .map(|w| {
                format!(
                    r#"{{"op":"inner_solve","file":"a{w}.hlo.txt","n":{n},"w":{w},"f":10}}"#
                )
            })
            .collect();
        let doc = format!(
            r#"{{"version":1,"dtype":"f64","artifacts":[{}]}}"#,
            arts.join(",")
        );
        let reg = ArtifactRegistry::from_json(Path::new("/tmp"), &doc).unwrap();
        for _ in 0..10 {
            let want = 1 + rng.below(widths.last().unwrap() + 10);
            let got = reg.inner_solve_bucket(n, want);
            let expect = widths.iter().copied().filter(|&w| w >= want).min();
            assert_eq!(got.map(|s| s.w), expect, "trial {trial} want={want}");
        }
        // non-matching n never routes
        assert!(reg.inner_solve_bucket(n + 1, 1).is_none());
    }
}

#[test]
fn prop_json_round_trip_random_documents() {
    let mut rng = Rng::new(304);
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 100.0).round()),
            3 => Json::Str(format!("s{}", rng.below(1000))),
            4 => {
                let len = rng.below(4);
                Json::Arr((0..len).map(|_| random_json(rng, depth - 1)).collect())
            }
            _ => {
                let len = rng.below(4);
                Json::Obj(
                    (0..len)
                        .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    for _ in 0..100 {
        let doc = random_json(&mut rng, 3);
        let text = doc.to_string();
        let parsed = parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(parsed, doc, "{text}");
    }
}

#[test]
fn prop_engine_solve_deterministic() {
    let mut rng = Rng::new(305);
    let n = 20;
    let p = 30;
    let mut x_cm = vec![0.0; n * p];
    for v in x_cm.iter_mut() {
        *v = rng.normal();
    }
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut e1 = NativeEngine;
    let mut e2 = NativeEngine;
    let a = celer::runtime::engine_cd_solve(&mut e1, &x_cm, n, p, &y, 0.5, 1e-8, 200, 5).unwrap();
    let b = celer::runtime::engine_cd_solve(&mut e2, &x_cm, n, p, &y, 0.5, 1e-8, 200, 5).unwrap();
    assert_eq!(a.beta, b.beta);
    assert_eq!(a.blocks, b.blocks);
}
