//! Runtime integration: the XLA/PJRT engine (AOT Pallas/JAX artifacts)
//! must agree with the native Rust engine operation by operation and on
//! a full Algorithm-1 solve.
//!
//! The XLA cross-checks need `make artifacts` (small profile) AND a
//! build with `--features xla`; in the default offline build they skip
//! with a notice, while the native-engine halves still run.

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::dual;
use celer::runtime::{default_artifacts_dir, engine_cd_solve, Engine, NativeEngine, XlaEngine};

/// Try to bring up the XLA engine; `None` (with a notice) when the AOT
/// artifacts are missing or the build lacks the `xla` feature.
///
/// Set `CELER_REQUIRE_XLA=1` to make a load failure fatal — use this in
/// artifacts-enabled CI so a manifest/HLO regression cannot silently
/// downgrade the cross-checks to skips.
fn try_load_xla() -> Option<XlaEngine> {
    match XlaEngine::load(&default_artifacts_dir()) {
        Ok(e) => Some(e),
        Err(e) => {
            if std::env::var("CELER_REQUIRE_XLA").map(|v| v == "1").unwrap_or(false) {
                panic!("CELER_REQUIRE_XLA=1 but the XLA engine failed to load: {e:#}");
            }
            eprintln!("skipping XLA cross-check: {e:#}");
            None
        }
    }
}

fn mini_dense() -> (Vec<f64>, usize, usize, Vec<f64>, f64) {
    let ds = synth::leukemia_mini(0);
    let (n, p) = (ds.x.n(), ds.x.p());
    let mut x_cm = Vec::new();
    ds.x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut x_cm);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
    (x_cm, n, p, ds.y.clone(), lambda)
}

#[test]
fn inner_solve_engines_agree() {
    let (x_cm, n, p, y, lambda) = mini_dense();
    // use the first 64-column block (matches the w=64 bucket exactly)
    let w = 64;
    let block = &x_cm[..n * w];
    let beta0 = vec![0.0; w];
    let mut native = NativeEngine;
    let (bn, rn) = native.inner_solve(block, n, w, &y, &beta0, lambda).unwrap();
    // native sanity: the residual matches y − Xβ for the returned β
    let mut expect = y.clone();
    for j in 0..w {
        if bn[j] != 0.0 {
            for i in 0..n {
                expect[i] -= bn[j] * block[j * n + i];
            }
        }
    }
    for i in 0..n {
        assert!((rn[i] - expect[i]).abs() < 1e-12, "native residual i={i}");
    }
    let Some(mut xla) = try_load_xla() else { return };
    let (bx, rx) = xla.inner_solve(block, n, w, &y, &beta0, lambda).unwrap();
    for j in 0..w {
        assert!((bn[j] - bx[j]).abs() < 1e-12, "beta[{j}]: {} vs {}", bn[j], bx[j]);
    }
    for i in 0..n {
        assert!((rn[i] - rx[i]).abs() < 1e-12);
    }
    let _ = p;
}

#[test]
fn inner_solve_bucket_padding_is_invariant() {
    // solving a 50-column problem through the 64-bucket must equal the
    // native engine on the unpadded 50 columns
    let (x_cm, n, _p, y, lambda) = mini_dense();
    let w = 50;
    let block = &x_cm[..n * w];
    let beta0 = vec![0.0; w];
    let mut native = NativeEngine;
    let (bn, _) = native.inner_solve(block, n, w, &y, &beta0, lambda).unwrap();
    // native padding invariance: 7 extra zero columns change nothing
    let pad = 7;
    let mut padded = block.to_vec();
    padded.extend(std::iter::repeat(0.0).take(pad * n));
    let beta0_pad = vec![0.0; w + pad];
    let (bp, _) = native.inner_solve(&padded, n, w + pad, &y, &beta0_pad, lambda).unwrap();
    for j in 0..w {
        assert!((bn[j] - bp[j]).abs() < 1e-15, "padding must not change beta[{j}]");
    }
    assert!(bp[w..].iter().all(|&b| b == 0.0));
    let Some(mut xla) = try_load_xla() else { return };
    let (bx, _) = xla.inner_solve(block, n, w, &y, &beta0, lambda).unwrap();
    assert_eq!(bx.len(), w, "padding must be stripped");
    for j in 0..w {
        assert!((bn[j] - bx[j]).abs() < 1e-12);
    }
}

#[test]
fn gap_scores_engines_agree() {
    let (x_cm, n, p, y, lambda) = mini_dense();
    let mut native = NativeEngine;
    let beta = vec![0.0; p];
    let theta: Vec<f64> = y.iter().map(|v| v * 0.1).collect();
    let (pn, dn, gn, sn) = native.gap_scores(&x_cm, n, p, &y, &beta, &theta, lambda).unwrap();
    assert!((gn - (pn - dn)).abs() < 1e-12, "gap = primal − dual");
    assert_eq!(sn.len(), p);
    let Some(mut xla) = try_load_xla() else { return };
    let (px, dx, gx, sx) = xla.gap_scores(&x_cm, n, p, &y, &beta, &theta, lambda).unwrap();
    assert!((pn - px).abs() < 1e-12);
    assert!((dn - dx).abs() < 1e-12);
    assert!((gn - gx).abs() < 1e-12);
    assert_eq!(sx.len(), p);
    for j in 0..p {
        assert!((sn[j] - sx[j]).abs() < 1e-10, "score[{j}]");
    }
}

#[test]
fn theta_res_engines_agree() {
    let (x_cm, n, p, y, lambda) = mini_dense();
    let mut native = NativeEngine;
    let (tn, ctn) = native.theta_res(&x_cm, n, p, &y, lambda).unwrap();
    // feasibility through the native path
    assert!(ctn.iter().all(|v| v.abs() <= 1.0 + 1e-12));
    let Some(mut xla) = try_load_xla() else { return };
    let (tx, ctx) = xla.theta_res(&x_cm, n, p, &y, lambda).unwrap();
    for i in 0..n {
        assert!((tn[i] - tx[i]).abs() < 1e-12);
    }
    for j in 0..p {
        assert!((ctn[j] - ctx[j]).abs() < 1e-12);
    }
    // feasibility through the xla path
    assert!(ctx.iter().all(|v| v.abs() <= 1.0 + 1e-12));
}

#[test]
fn extrapolate_engines_agree() {
    let n = 48;
    let k = 5;
    let mut rng = celer::util::rng::Rng::new(9);
    let rbuf: Vec<f64> = (0..(k + 1) * n).map(|_| rng.normal()).collect();
    let mut native = NativeEngine;
    let (rn, pn) = native.extrapolate(&rbuf, k, n).unwrap();
    assert!(rn.iter().all(|v| v.is_finite()));
    let Some(mut xla) = try_load_xla() else { return };
    let (rx, px) = xla.extrapolate(&rbuf, k, n).unwrap();
    assert!((pn - px).abs() < 1e-9 * pn.abs().max(1.0), "min pivots: {pn} vs {px}");
    for i in 0..n {
        assert!((rn[i] - rx[i]).abs() < 1e-9, "r_accel[{i}]: {} vs {}", rn[i], rx[i]);
    }
}

#[test]
fn full_solve_engines_agree() {
    let (x_cm, n, p, y, lambda) = mini_dense();
    let mut native = NativeEngine;
    let a = engine_cd_solve(&mut native, &x_cm, n, p, &y, lambda, 1e-8, 500, 5).unwrap();
    assert!(a.converged, "native engine solve converges, gap={}", a.gap);
    let Some(mut xla) = try_load_xla() else { return };
    let b = engine_cd_solve(&mut xla, &x_cm, n, p, &y, lambda, 1e-8, 500, 5).unwrap();
    assert!(b.converged);
    assert_eq!(a.blocks, b.blocks, "identical schedule");
    let max_diff = a
        .beta
        .iter()
        .zip(&b.beta)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    assert!(max_diff < 1e-8, "max |Δβ| = {max_diff}");
}

#[test]
fn missing_bucket_reports_useful_error() {
    let Some(mut xla) = try_load_xla() else { return };
    let err = xla
        .inner_solve(&vec![0.0; 10 * 10_000], 10, 10_000, &vec![0.0; 10], &vec![0.0; 10_000], 1.0)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no inner_solve artifact"), "{msg}");
}
