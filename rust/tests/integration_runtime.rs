//! Runtime integration: the XLA/PJRT engine (AOT Pallas/JAX artifacts)
//! must agree with the native Rust engine operation by operation and on
//! a full Algorithm-1 solve. Requires `make artifacts` (small profile).

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::dual;
use celer::runtime::{engine_cd_solve, default_artifacts_dir, Engine, NativeEngine, XlaEngine};

fn load_xla() -> XlaEngine {
    XlaEngine::load(&default_artifacts_dir())
        .expect("artifacts missing — run `make artifacts` before `cargo test`")
}

fn mini_dense() -> (Vec<f64>, usize, usize, Vec<f64>, f64) {
    let ds = synth::leukemia_mini(0);
    let (n, p) = (ds.x.n(), ds.x.p());
    let mut x_cm = Vec::new();
    ds.x.gather_dense(&(0..p).collect::<Vec<_>>(), &mut x_cm);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 10.0;
    (x_cm, n, p, ds.y.clone(), lambda)
}

#[test]
fn inner_solve_engines_agree() {
    let (x_cm, n, p, y, lambda) = mini_dense();
    // use the first 64-column block (matches the w=64 bucket exactly)
    let w = 64;
    let block = &x_cm[..n * w];
    let beta0 = vec![0.0; w];
    let mut native = NativeEngine;
    let mut xla = load_xla();
    let (bn, rn) = native.inner_solve(block, n, w, &y, &beta0, lambda).unwrap();
    let (bx, rx) = xla.inner_solve(block, n, w, &y, &beta0, lambda).unwrap();
    for j in 0..w {
        assert!((bn[j] - bx[j]).abs() < 1e-12, "beta[{j}]: {} vs {}", bn[j], bx[j]);
    }
    for i in 0..n {
        assert!((rn[i] - rx[i]).abs() < 1e-12);
    }
    let _ = p;
}

#[test]
fn inner_solve_bucket_padding_is_invariant() {
    // solving a 50-column problem through the 64-bucket must equal the
    // native engine on the unpadded 50 columns
    let (x_cm, n, _p, y, lambda) = mini_dense();
    let w = 50;
    let block = &x_cm[..n * w];
    let beta0 = vec![0.0; w];
    let mut native = NativeEngine;
    let mut xla = load_xla();
    let (bn, _) = native.inner_solve(block, n, w, &y, &beta0, lambda).unwrap();
    let (bx, _) = xla.inner_solve(block, n, w, &y, &beta0, lambda).unwrap();
    assert_eq!(bx.len(), w, "padding must be stripped");
    for j in 0..w {
        assert!((bn[j] - bx[j]).abs() < 1e-12);
    }
}

#[test]
fn gap_scores_engines_agree() {
    let (x_cm, n, p, y, lambda) = mini_dense();
    let mut native = NativeEngine;
    let mut xla = load_xla();
    let beta = vec![0.0; p];
    let theta: Vec<f64> = y.iter().map(|v| v * 0.1).collect();
    let (pn, dn, gn, sn) = native.gap_scores(&x_cm, n, p, &y, &beta, &theta, lambda).unwrap();
    let (px, dx, gx, sx) = xla.gap_scores(&x_cm, n, p, &y, &beta, &theta, lambda).unwrap();
    assert!((pn - px).abs() < 1e-12);
    assert!((dn - dx).abs() < 1e-12);
    assert!((gn - gx).abs() < 1e-12);
    assert_eq!(sx.len(), p);
    for j in 0..p {
        assert!((sn[j] - sx[j]).abs() < 1e-10, "score[{j}]");
    }
}

#[test]
fn theta_res_engines_agree() {
    let (x_cm, n, p, y, lambda) = mini_dense();
    let mut native = NativeEngine;
    let mut xla = load_xla();
    let (tn, ctn) = native.theta_res(&x_cm, n, p, &y, lambda).unwrap();
    let (tx, ctx) = xla.theta_res(&x_cm, n, p, &y, lambda).unwrap();
    for i in 0..n {
        assert!((tn[i] - tx[i]).abs() < 1e-12);
    }
    for j in 0..p {
        assert!((ctn[j] - ctx[j]).abs() < 1e-12);
    }
    // feasibility through the xla path
    assert!(ctx.iter().all(|v| v.abs() <= 1.0 + 1e-12));
}

#[test]
fn extrapolate_engines_agree() {
    let n = 48;
    let k = 5;
    let mut rng = celer::util::rng::Rng::new(9);
    let rbuf: Vec<f64> = (0..(k + 1) * n).map(|_| rng.normal()).collect();
    let mut native = NativeEngine;
    let mut xla = load_xla();
    let (rn, pn) = native.extrapolate(&rbuf, k, n).unwrap();
    let (rx, px) = xla.extrapolate(&rbuf, k, n).unwrap();
    assert!((pn - px).abs() < 1e-9 * pn.abs().max(1.0), "min pivots: {pn} vs {px}");
    for i in 0..n {
        assert!((rn[i] - rx[i]).abs() < 1e-9, "r_accel[{i}]: {} vs {}", rn[i], rx[i]);
    }
}

#[test]
fn full_solve_engines_agree() {
    let (x_cm, n, p, y, lambda) = mini_dense();
    let mut native = NativeEngine;
    let mut xla = load_xla();
    let a = engine_cd_solve(&mut native, &x_cm, n, p, &y, lambda, 1e-8, 500, 5).unwrap();
    let b = engine_cd_solve(&mut xla, &x_cm, n, p, &y, lambda, 1e-8, 500, 5).unwrap();
    assert!(a.converged && b.converged);
    assert_eq!(a.blocks, b.blocks, "identical schedule");
    let max_diff = a
        .beta
        .iter()
        .zip(&b.beta)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    assert!(max_diff < 1e-8, "max |Δβ| = {max_diff}");
}

#[test]
fn missing_bucket_reports_useful_error() {
    let mut xla = load_xla();
    let err = xla
        .inner_solve(&vec![0.0; 10 * 10_000], 10, 10_000, &vec![0.0; 10], &vec![0.0; 10_000], 1.0)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no inner_solve artifact"), "{msg}");
}
