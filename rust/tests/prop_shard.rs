//! Property tests for the sharded column store (`data::shard`).
//!
//! The contracts pinned here:
//!
//! 1. **Sharding is invisible to the math**: every `DesignOps` kernel
//!    on a `ShardedStore` returns the exact bits the single-file
//!    `OocColumnStore` and the in-memory `CscMatrix` return — single
//!    columns, lane ops, full scans — for shard counts 1, 2, 3 and
//!    one-column-per-shard, under pooled and serial execution, and for
//!    deliberately misaligned shard boundaries.
//! 2. **λ-path bit-identity** (the PR 10 acceptance criterion): the
//!    lasso path on `DesignMatrix::Sharded` equals the path on a
//!    single store and on the resident CSC bit-for-bit — per-step λ,
//!    gap and β — for the sequential and batched schedulers, pooled
//!    and serial.
//! 3. **Streamed f32 stays streamed**: the f32 sweep mode over a
//!    store never materializes a full-design f32 copy — the peak
//!    resident shadow bytes stay within the advertised per-stream
//!    bound (chunk cache × chunk size per shard) — and its f64 gap
//!    certificates and β match the resident-shadow f32 mode bitwise.
//! 4. **Shard defects are typed**: a corrupt, truncated, or missing
//!    shard file — or shards of different datasets mixed into one
//!    open — fails with `SolveError::StoreFormat`, not a panic.

use celer::data::csc::CscMatrix;
use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::ooc::{self, OocColumnStore};
use celer::data::shard::{self, ShardedStore};
use celer::data::synth;
use celer::solvers::batch::BatchConfig;
use celer::solvers::engine::Workspace;
use celer::solvers::path::{
    lambda_grid, lasso_path, run_path, run_path_batched, PathResult, PathSolver,
};
use celer::solvers::Precision;
use celer::util::error::SolveError;
use celer::util::par;
use celer::util::rng::Rng;
use std::path::PathBuf;

/// Unique temp path per test so the suite can run in parallel.
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("celer_prop_shard_{}_{name}", std::process::id()))
}

struct TmpFiles(Vec<PathBuf>);
impl Drop for TmpFiles {
    fn drop(&mut self) {
        for p in &self.0 {
            let _ = std::fs::remove_file(p);
        }
    }
}

fn rand_vec(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn random_csc(seed: u64, n: usize, p: usize, density: f64) -> (CscMatrix, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut dense = vec![0.0; n * p];
    for v in dense.iter_mut() {
        if rng.uniform() < density {
            *v = rng.normal();
        }
    }
    let y = (0..n).map(|_| rng.normal()).collect();
    (CscMatrix::from_dense(n, p, &dense), y)
}

/// Write `x` as `k` shards at fresh temp paths and open the result
/// with small chunks so every shard genuinely streams.
fn sharded(
    tag: &str,
    x: &CscMatrix,
    y: &[f64],
    bounds: &[usize],
) -> (ShardedStore, TmpFiles) {
    let k = bounds.len() - 1;
    let paths: Vec<PathBuf> = (0..k).map(|s| tmp(&format!("{tag}.s{s}"))).collect();
    shard::write_sharded_store_with_bounds(&paths, x, y, bounds).unwrap();
    let store = ShardedStore::open_with(&paths, 1 << 10, 3).unwrap();
    (store, TmpFiles(paths))
}

#[test]
fn every_kernel_matches_csc_across_shard_counts() {
    let (csc, y) = random_csc(7, 50, 23, 0.4);
    let (n, p) = (csc.n(), csc.p());
    let single_path = tmp("kernels_single.cstore");
    let _g = TmpFiles(vec![single_path.clone()]);
    ooc::write_store(&single_path, &csc, &y).unwrap();
    let single = OocColumnStore::open_with(&single_path, 1 << 10, 3).unwrap();

    let v = rand_vec(8, n);
    let lanes: Vec<usize> = (0..4).collect();
    let vl = rand_vec(9, 4 * n);
    let alphas = [1e-3, -2e-3, 5e-4, -1e-4];
    let w = rand_vec(10, n).iter().map(|x| x.abs() + 0.1).collect::<Vec<_>>();
    let beta = rand_vec(11, p);

    // even shard counts 1, 2, 3 and one-column-per-shard, plus two
    // deliberately misaligned splits (lopsided and singleton-edged)
    let mut all_bounds: Vec<Vec<usize>> = [1usize, 2, 3, p]
        .iter()
        .map(|&k| shard::even_bounds(p, k))
        .collect();
    all_bounds.push(vec![0, 1, p - 1, p]);
    all_bounds.push(vec![0, p - 2, p]);

    for bounds in &all_bounds {
        let (store, _files) = sharded(&format!("k{}", bounds.len() - 1), &csc, &y, bounds);
        assert_eq!((store.n(), store.p(), store.nnz()), (n, p, csc.nnz()));
        assert_eq!(store.read_labels().unwrap(), y);

        for j in 0..p {
            assert_eq!(
                store.col_dot(j, &v).to_bits(),
                csc.col_dot(j, &v).to_bits(),
                "col_dot j={j} bounds={bounds:?}"
            );
            assert_eq!(store.col_norm_sq(j).to_bits(), csc.col_norm_sq(j).to_bits());
            assert_eq!(store.col_nnz(j), csc.col_nnz(j));
            assert_eq!(
                store.col_wnorm_sq(j, &w).to_bits(),
                csc.col_wnorm_sq(j, &w).to_bits()
            );

            let mut out_s = [0.0f64; 4];
            let mut out_c = [0.0f64; 4];
            store.col_dot_lanes(j, &vl, n, &lanes, &mut out_s);
            csc.col_dot_lanes(j, &vl, n, &lanes, &mut out_c);
            assert_eq!(out_s.map(f64::to_bits), out_c.map(f64::to_bits), "lane dot j={j}");

            let mut vs = vl.clone();
            let mut vc = vl.clone();
            store.col_axpy_lanes(j, &alphas, &mut vs, n, &lanes);
            csc.col_axpy_lanes(j, &alphas, &mut vc, n, &lanes);
            assert_eq!(vs, vc, "lane axpy j={j}");
        }

        // full scans: pooled AND serial, vs the CSC and the single store
        let mut scan_sh = vec![0.0; p];
        let mut scan_c = vec![0.0; p];
        let mut scan_1 = vec![0.0; p];
        store.xt_vec(&v, &mut scan_sh);
        csc.xt_vec(&v, &mut scan_c);
        single.xt_vec(&v, &mut scan_1);
        assert_eq!(scan_sh, scan_c, "xt_vec bounds={bounds:?}");
        assert_eq!(scan_sh, scan_1, "xt_vec sharded vs single store");
        assert_eq!(store.xt_abs_max(&v).to_bits(), csc.xt_abs_max(&v).to_bits());
        let mut m_sh = vec![0.0; p];
        let mut m_c = vec![0.0; p];
        let a_sh = store.xt_vec_abs_max(&v, &mut m_sh);
        let a_c = csc.xt_vec_abs_max(&v, &mut m_c);
        assert_eq!(a_sh.to_bits(), a_c.to_bits(), "xt_vec_abs_max max");
        assert_eq!(m_sh, m_c, "xt_vec_abs_max fill");
        assert_eq!(store.col_norms_sq(), csc.col_norms_sq());
        let mut mv_sh = vec![0.0; n];
        let mut mv_c = vec![0.0; n];
        store.matvec(&beta, &mut mv_sh);
        csc.matvec(&beta, &mut mv_c);
        assert_eq!(mv_sh, mv_c, "matvec");

        let serial = par::run_serial(|| {
            let mut out = vec![0.0; p];
            store.xt_vec(&v, &mut out);
            (out, store.xt_abs_max(&v))
        });
        assert_eq!(serial.0, scan_c, "serial sharded scan == csc scan");
        assert_eq!(serial.1.to_bits(), csc.xt_abs_max(&v).to_bits(), "serial abs max");

        // working-set restriction and materialization round-trip
        let keep: Vec<usize> = (0..p).step_by(5).collect();
        let sub_sh = store.select_columns_csc(&keep);
        let sub_c = csc.select_columns(&keep);
        for (jj, _) in keep.iter().enumerate() {
            assert_eq!(sub_sh.col(jj), sub_c.col(jj));
        }
        let round = store.to_csc();
        for j in 0..p {
            assert_eq!(round.col(j), csc.col(j), "to_csc col {j}");
        }
    }
}

fn assert_paths_bit_identical(a: &PathResult, b: &PathResult, what: &str) {
    assert_eq!(a.steps.len(), b.steps.len(), "{what}: step count");
    for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
        assert_eq!(sa.lambda.to_bits(), sb.lambda.to_bits(), "{what}: λ#{i}");
        assert_eq!(sa.gap.to_bits(), sb.gap.to_bits(), "{what}: gap#{i}");
        let ba = sa.beta.as_ref().expect("store_betas");
        let bb = sb.beta.as_ref().expect("store_betas");
        let diff = ba.iter().zip(bb).position(|(x, y)| x.to_bits() != y.to_bits());
        assert_eq!(diff, None, "{what}: β#{i} first differing coefficient {diff:?}");
    }
}

#[test]
fn lambda_path_on_sharded_store_is_bit_identical() {
    // The acceptance criterion: the same λ-grid solved on the sharded
    // store, the single-file store, and the resident CSC must produce
    // identical certificates under every scheduler.
    let ds = synth::finance_mini(31);
    let DesignMatrix::Sparse(ref csc) = ds.x else { panic!("finance_mini is sparse") };
    let p = csc.p();

    let single_path = tmp("path_single.cstore");
    let _g = TmpFiles(vec![single_path.clone()]);
    ooc::write_store(&single_path, csc, &ds.y).unwrap();
    let single = OocColumnStore::open_with(&single_path, 1 << 12, 3).unwrap();
    assert!(single.nchunks() > 4, "want a chunked stream");
    let x_single = DesignMatrix::Ooc(single);

    // a 3-way split with deliberately uneven boundaries: the middle
    // shard owns almost everything, the edges are slivers
    let (sh3, _f3) = sharded("path3", csc, &ds.y, &[0, 7, p - 3, p]);
    let (sh2, _f2) = sharded("path2", csc, &ds.y, &shard::even_bounds(p, 2));
    let x_sh3 = DesignMatrix::Sharded(sh3);
    let x_sh2 = DesignMatrix::Sharded(sh2);

    let lam_max = celer::lasso::dual::lambda_max(&ds.x, &ds.y);
    let grid = lambda_grid(lam_max, 0.1, 6);
    let solver = PathSolver::by_name("gapsafe-cd-accel", 1e-9).unwrap();

    // sequential scheduler, pooled then serial
    let mem = run_path(&ds.x, &ds.y, &grid, &solver, true);
    assert!(mem.all_converged());
    let one = run_path(&x_single, &ds.y, &grid, &solver, true);
    assert_paths_bit_identical(&mem, &one, "single store, sequential pooled");
    for (x_sh, what) in [(&x_sh2, "2 shards"), (&x_sh3, "3 shards misaligned")] {
        let pooled = run_path(x_sh, &ds.y, &grid, &solver, true);
        assert_paths_bit_identical(&mem, &pooled, &format!("{what}, sequential pooled"));
        let serial = par::run_serial(|| run_path(x_sh, &ds.y, &grid, &solver, true));
        assert_paths_bit_identical(&mem, &serial, &format!("{what}, sequential serial"));
    }

    // batched lane scheduler over the same stores
    let mem_b = lasso_path(&ds.x, &ds.y, &grid, 1e-9, 3, true, &celer::penalty::L1);
    assert!(mem_b.all_converged());
    let one_b = lasso_path(&x_single, &ds.y, &grid, 1e-9, 3, true, &celer::penalty::L1);
    assert_paths_bit_identical(&mem_b, &one_b, "single store, batched");
    for (x_sh, what) in [(&x_sh2, "2 shards"), (&x_sh3, "3 shards misaligned")] {
        let sh_b = lasso_path(x_sh, &ds.y, &grid, 1e-9, 3, true, &celer::penalty::L1);
        assert_paths_bit_identical(&mem_b, &sh_b, &format!("{what}, batched pooled"));
        let sh_s =
            par::run_serial(|| lasso_path(x_sh, &ds.y, &grid, 1e-9, 3, true, &celer::penalty::L1));
        assert_paths_bit_identical(&mem_b, &sh_s, &format!("{what}, batched serial"));
    }
}

#[test]
fn streamed_f32_matches_resident_f32_and_bounds_memory() {
    let ds = synth::finance_mini(41);
    let DesignMatrix::Sparse(ref csc) = ds.x else { panic!("finance_mini is sparse") };
    let p = csc.p();

    let single_path = tmp("f32_single.cstore");
    let _g = TmpFiles(vec![single_path.clone()]);
    ooc::write_store(&single_path, csc, &ds.y).unwrap();
    let single = OocColumnStore::open_with(&single_path, 1 << 12, 3).unwrap();
    let nchunks = single.nchunks();
    assert!(nchunks > 4, "want a chunked stream, got {nchunks} chunks");
    let x_single = DesignMatrix::Ooc(single);
    let (sh2, _f2) = sharded("f32_sh2", csc, &ds.y, &shard::even_bounds(p, 2));
    let x_sh2 = DesignMatrix::Sharded(sh2.clone());

    let lam_max = celer::lasso::dual::lambda_max(&ds.x, &ds.y);
    let grid = lambda_grid(lam_max, 0.1, 5);
    let cfg = BatchConfig { precision: Precision::F32, lanes: 3, tol: 1e-7, ..Default::default() };

    // resident f32 shadow (CSC) vs streamed f32 shadow (store, sharded
    // store): identical f32 iterates, identical f64 certificates.
    let mut ws = Workspace::new();
    let res = run_path_batched(&ds.x, &ds.y, &grid, &cfg, true, &mut ws);
    assert!(res.all_converged());
    let one = run_path_batched(&x_single, &ds.y, &grid, &cfg, true, &mut ws);
    assert_paths_bit_identical(&res, &one, "streamed f32, single store");
    let two = run_path_batched(&x_sh2, &ds.y, &grid, &cfg, true, &mut ws);
    assert_paths_bit_identical(&res, &two, "streamed f32, 2 shards");

    // The memory contract: a full sweep of the streamed shadow never
    // holds more f32 chunk bytes than the advertised bound — chunk
    // cache × max chunk entries per shard — and that bound is well
    // under a full-design f32 copy (8 bytes per stored entry).
    let shadow = x_sh2.shadow_f32();
    let nf = rand_vec(42, csc.n()).iter().map(|&x| x as f32).collect::<Vec<f32>>();
    let mut acc = 0.0f32;
    for j in 0..p {
        acc += shadow.col_dot(j, &nf);
    }
    assert!(acc.is_finite());
    let (resident, peak, bound) = shadow.stream_stats().expect("streamed shadow");
    assert!(peak > 0, "the sweep must have materialized f32 chunks");
    assert!(resident <= peak, "resident {resident} > peak {peak}");
    assert!(peak <= bound, "peak resident f32 bytes {peak} exceed the bound {bound}");
    let full_copy = (csc.nnz() * 8) as u64;
    assert!(
        bound < full_copy / 2,
        "bound {bound} is not meaningfully below a full f32 copy ({full_copy})"
    );

    // and the resident-mode shadow of the same matrix agrees bitwise
    let shadow_res = ds.x.shadow_f32();
    assert!(shadow_res.stream_stats().is_none(), "CSC shadow is resident");
    for j in (0..p).step_by(13) {
        assert_eq!(
            shadow.col_dot(j, &nf).to_bits(),
            shadow_res.col_dot(j, &nf).to_bits(),
            "streamed vs resident f32 col_dot j={j}"
        );
    }
}

#[test]
fn corrupt_missing_or_mixed_shards_fail_typed() {
    let (csc, y) = random_csc(51, 40, 12, 0.5);
    let paths = vec![tmp("typed.s0"), tmp("typed.s1")];
    let _g = TmpFiles(paths.clone());
    shard::write_sharded_store(&paths, &csc, &y).unwrap();
    let good = std::fs::read(&paths[1]).unwrap();

    let expect_format = |what: &str| match ShardedStore::open(&paths) {
        Err(SolveError::StoreFormat { .. }) => {}
        other => panic!("{what}: expected StoreFormat, got {other:?}"),
    };

    // truncated shard payload
    std::fs::write(&paths[1], &good[..good.len() - 5]).unwrap();
    expect_format("truncated shard");
    // corrupt shard magic
    let mut bad = good.clone();
    bad[0] ^= 0xFF;
    std::fs::write(&paths[1], &bad).unwrap();
    expect_format("corrupt shard magic");
    // missing shard file
    std::fs::remove_file(&paths[1]).unwrap();
    expect_format("missing shard");

    // shards of different datasets (different labels) cannot be mixed
    let (csc2, y2) = random_csc(52, 40, 12, 0.5);
    shard::write_sharded_store(&[paths[1].clone()], &csc2, &y2).unwrap();
    expect_format("mixed datasets");

    // shards disagreeing on n are rejected too
    let (csc3, y3) = random_csc(53, 39, 12, 0.5);
    shard::write_sharded_store(&[paths[1].clone()], &csc3, &y3).unwrap();
    expect_format("row count mismatch");

    // empty path list is a typed error as well
    assert!(matches!(
        ShardedStore::open(&[]),
        Err(SolveError::StoreFormat { .. })
    ));
}
