//! Gap Safe screening integration: safety, convergence of the screened
//! set, and the θ_accel-screens-faster mechanism behind Figure 3.

use celer::data::design::DesignOps;
use celer::data::synth;
use celer::lasso::{dual, primal};
use celer::solvers::cd::{cd_solve, CdConfig};

#[test]
fn screening_preserves_the_optimum() {
    let ds = synth::leukemia_mini(110);
    for ratio in [0.5, 0.2, 0.08] {
        let lambda = dual::lambda_max(&ds.x, &ds.y) * ratio;
        let screen = cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &CdConfig { tol: 1e-9, screen: true, ..Default::default() },
        );
        let plain = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol: 1e-9, ..Default::default() });
        let p = |b: &[f64]| primal::primal(&ds.x, &ds.y, b, lambda);
        assert!(
            (p(&screen.beta) - p(&plain.beta)).abs() < 1e-7,
            "ratio {ratio}: {} vs {}",
            p(&screen.beta),
            p(&plain.beta)
        );
    }
}

#[test]
fn screening_is_safe_vs_high_precision_support() {
    // every feature the dynamic rule screened must be zero in a
    // machine-precision solution
    let ds = synth::leukemia_mini(111);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 5.0;
    let reference = cd_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &CdConfig { tol: 1e-13, max_epochs: 200_000, ..Default::default() },
    );
    assert!(reference.converged);
    // re-run with screening, capturing the screened set implicitly: any
    // feature with β=0 in the screened run AND nonzero in the reference
    // would indicate a wrongly-discarded feature IF the objective differs.
    let screened_run = cd_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &CdConfig { tol: 1e-12, screen: true, ..Default::default() },
    );
    for j in 0..ds.x.p() {
        if reference.beta[j].abs() > 1e-7 {
            assert!(
                screened_run.beta[j].abs() > 0.0,
                "feature {j} (β̂={}) was wrongly screened",
                reference.beta[j]
            );
        }
    }
}

#[test]
fn screening_converges_toward_support_size() {
    let ds = synth::leukemia_mini(112);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 4.0;
    let out = cd_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &CdConfig { tol: 1e-12, screen: true, trace: true, ..Default::default() },
    );
    assert!(out.converged);
    let screened = out.trace.last().unwrap().n_screened;
    let support = out.beta.iter().filter(|&&b| b != 0.0).count();
    let active = ds.x.p() - screened;
    assert!(
        active <= support + 25,
        "active {active} should approach support {support}"
    );
}

#[test]
fn accel_screening_not_slower() {
    let ds = synth::leukemia_mini(113);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 5.0;
    let base = CdConfig { tol: 1e-10, screen: true, trace: true, ..Default::default() };
    let res = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { extrapolate: false, ..base.clone() });
    let acc = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { extrapolate: true, ..base });
    assert!(
        acc.epochs <= res.epochs,
        "θ_accel should converge in no more epochs: {} vs {}",
        acc.epochs,
        res.epochs
    );
}

#[test]
fn screening_counts_monotone() {
    let ds = synth::leukemia_mini(114);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 6.0;
    let out = cd_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &CdConfig { tol: 1e-11, screen: true, trace: true, ..Default::default() },
    );
    let counts: Vec<usize> = out.trace.iter().map(|c| c.n_screened).collect();
    for w in counts.windows(2) {
        assert!(w[1] >= w[0], "screened set only grows: {counts:?}");
    }
}

#[test]
fn screening_on_sparse_data() {
    let ds = synth::finance_mini(115);
    let lambda = dual::lambda_max(&ds.x, &ds.y) / 5.0;
    let plain = cd_solve(&ds.x, &ds.y, lambda, None, &CdConfig { tol: 1e-9, ..Default::default() });
    let screen = cd_solve(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &CdConfig { tol: 1e-9, screen: true, trace: true, ..Default::default() },
    );
    let p = |b: &[f64]| primal::primal(&ds.x, &ds.y, b, lambda);
    assert!((p(&plain.beta) - p(&screen.beta)).abs() < 1e-7);
    assert!(
        screen.trace.last().unwrap().n_screened > ds.x.p() / 2,
        "most of the sparse problem should be screened at λ_max/5"
    );
}
