//! Property tests for the penalty abstraction.
//!
//! 1. **Bit-identity pins**: the `P = L1` instantiation of the
//!    penalty-generic machinery is bitwise equal to faithful test-local
//!    ports of the pre-penalty code —
//!    a. the engine loop (`cd_solve` → `engine::solve_penalty` with
//!       `L1`) against the same legacy CD port `prop_glm.rs` pins,
//!       dense + CSC, screening on/off, extrapolation on/off;
//!    b. the CELER outer loop (`celer_solve` →
//!       `celer_solve_penalty` with `L1`) against a port of the
//!       pre-penalty outer loop — per-iteration gaps, working-set
//!       sizes, inner epoch counts and dual winners included.
//! 2. **Conformance suite** run against EVERY `Penalty` impl (ℓ₁,
//!    elastic net, weighted ℓ₁, group-ℓ₂), dense + CSC: prox
//!    optimality, dual-norm/value Fenchel consistency, `lambda_max`
//!    correctness (β̂ = 0 exactly at λ ≥ λ_max, support at 0.8·λ_max),
//!    and Gap Safe screening safety (screened ⇒ zero in a tight
//!    unscreened reference).
//! 3. **Elastic-net reduction**: EN(λ, α) on X equals the Lasso at λα
//!    on the augmented design [X; √(λ(1−α))·I] — objectives and
//!    supports.
//! 4. **Weighted-ℓ₁ edge weights**: w = 0 features are never screened
//!    and carry a free coefficient; w = ∞ features are exactly zero.

use celer::data::dense::DenseMatrix;
use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::synth::{self, SynthDataset};
use celer::data::view::DesignView;
use celer::datafit::{Datafit, Quadratic};
use celer::extrapolation::ResidualBuffer;
use celer::lasso::{dual, primal};
use celer::penalty::{ElasticNet, GroupLasso, Penalty, WeightedL1, L1};
use celer::solvers::cd::{cd_solve, CdConfig};
use celer::solvers::celer::{celer_penalty_solve_on_ws, celer_solve_on, CelerConfig};
use celer::solvers::engine::{self, CdStrategy, EngineConfig, Init, StopRule, Workspace};
use celer::solvers::{DualScratch, SolveResult};
use celer::util::linalg::dot;
use celer::util::rng::Rng;
use celer::ws::build_working_set;

// ---------------------------------------------------------------------
// 1a. engine pin: P = L1 vs the pre-penalty engine loop
// ---------------------------------------------------------------------

/// Faithful port of the pre-penalty quadratic dual update (Eq. 4
/// rescale + fused D(θ_res) + θ_accel + Eq. 13 monotone best), exactly
/// as `DualState::update` hardcoded it before penalties existed —
/// identical to the port `prop_glm.rs` pins the datafit refactor with.
struct LegacyDual {
    buffer: ResidualBuffer,
    theta: Vec<f64>,
    xtheta: Vec<f64>,
    dval: f64,
    y_norm_sq: f64,
    extrapolate: bool,
    monotone: bool,
}

impl LegacyDual {
    fn new(n: usize, p: usize, k: usize, extrapolate: bool, monotone: bool) -> Self {
        LegacyDual {
            buffer: ResidualBuffer::new(k.max(1)),
            theta: vec![0.0; n],
            xtheta: vec![0.0; p],
            dval: f64::NEG_INFINITY,
            y_norm_sq: f64::NAN,
            extrapolate,
            monotone,
        }
    }

    fn update(
        &mut self,
        x: &DesignMatrix,
        y: &[f64],
        lambda: f64,
        r: &[f64],
        scratch: &mut DualScratch,
    ) {
        self.buffer.push(r);
        let n = y.len();
        let p = x.p();
        scratch.xtr.resize(p, 0.0);
        if self.y_norm_sq.is_nan() {
            self.y_norm_sq = dot(y, y);
        }
        let denom = lambda.max(x.xt_vec_abs_max(r, &mut scratch.xtr));
        let inv = 1.0 / denom;
        let d_res = {
            let mut dist_sq = 0.0;
            for i in 0..n {
                let d = r[i] * inv - y[i] / lambda;
                dist_sq += d * d;
            }
            0.5 * self.y_norm_sq - 0.5 * lambda * lambda * dist_sq
        };
        let mut best_val = d_res;
        let mut best_is_accel = false;
        if self.extrapolate && self.buffer.extrapolate_into(&mut scratch.extrap) {
            let r_acc = &scratch.extrap.r_accel;
            scratch.xtr_acc.resize(p, 0.0);
            scratch.theta_acc.resize(n, 0.0);
            let denom_a = lambda.max(x.xt_vec_abs_max(r_acc, &mut scratch.xtr_acc));
            let inv_a = 1.0 / denom_a;
            for (t, &v) in scratch.theta_acc.iter_mut().zip(r_acc.iter()) {
                *t = v * inv_a;
            }
            for v in scratch.xtr_acc.iter_mut() {
                *v *= inv_a;
            }
            let d_acc = dual::dual_objective_cached(y, &scratch.theta_acc, lambda, self.y_norm_sq);
            if d_acc > best_val {
                best_val = d_acc;
                best_is_accel = true;
            }
        }
        if self.monotone && self.dval >= best_val {
            return;
        }
        if best_is_accel {
            self.theta.clear();
            self.theta.extend_from_slice(&scratch.theta_acc);
            self.xtheta.clear();
            self.xtheta.extend_from_slice(&scratch.xtr_acc);
            self.dval = best_val;
        } else {
            self.theta.clear();
            self.theta.extend(r.iter().map(|&v| v * inv));
            self.xtheta.clear();
            self.xtheta.extend(scratch.xtr.iter().map(|&v| v * inv));
            self.dval = d_res;
        }
    }
}

struct LegacyOut {
    beta: Vec<f64>,
    r: Vec<f64>,
    theta: Vec<f64>,
    gap: f64,
    epochs: usize,
    converged: bool,
}

/// Faithful port of the pre-penalty `engine::solve` ℓ₁ loop under
/// `StopRule::DualityGap` with `CdStrategy`: CD epochs over the active
/// set with the plain soft-threshold, gap checks every `gap_freq`
/// epochs, hardcoded ℓ₁ primal / dual / Gap Safe screening, in the
/// exact statement order of the old engine.
#[allow(clippy::too_many_arguments)]
fn legacy_cd_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    tol: f64,
    max_epochs: usize,
    gap_freq: usize,
    k: usize,
    extrapolate: bool,
    screen: bool,
) -> LegacyOut {
    let n = x.n();
    let p = x.p();
    let mut norms_sq = vec![0.0; p];
    for (j, v) in norms_sq.iter_mut().enumerate() {
        *v = x.col_norm_sq(j);
    }
    let col_norms: Vec<f64> = norms_sq.iter().map(|v| v.sqrt()).collect();
    let mut beta = vec![0.0; p];
    let mut r = vec![0.0; n];
    primal::residual(x, y, &beta, &mut r);
    let mut active: Vec<usize> = (0..p).filter(|&j| norms_sq[j] > 0.0).collect();
    let mut dualst = LegacyDual::new(n, p, k.max(1), extrapolate, true);
    let mut scratch = DualScratch::default();
    scratch.prepare(n, p);
    let mut screened = vec![false; p];
    let mut scr_active: Vec<usize> = (0..p).collect();
    let mut r_check = vec![0.0; n];
    let mut gap = f64::INFINITY;
    let mut epochs = 0usize;
    let mut converged = false;
    for epoch in 1..=max_epochs {
        epochs = epoch;
        // ---- CdStrategy::epoch, verbatim (ℓ₁ soft-threshold) ----
        for &j in &active {
            let nrm = norms_sq[j];
            let g = x.col_dot(j, &r);
            let old = beta[j];
            let new = celer::util::soft_threshold(old + g / nrm, lambda / nrm);
            if new != old {
                x.col_axpy(j, old - new, &mut r);
                beta[j] = new;
            }
        }
        if epoch % gap_freq == 0 || epoch == max_epochs {
            r_check.copy_from_slice(&r);
            dualst.update(x, y, lambda, &r_check, &mut scratch);
            let p_val = primal::primal_from_residual(&r_check, &beta, lambda);
            gap = p_val - dualst.dval;
            if screen && gap > tol {
                // ---- ScreeningState::screen, verbatim ----
                let radius = celer::screening::gap_safe_radius(gap, lambda);
                let threshold = radius + 1e-12;
                scr_active.retain(|&j| {
                    let keep = celer::screening::d_score(dualst.xtheta[j].abs(), col_norms[j])
                        <= threshold;
                    if !keep {
                        screened[j] = true;
                        if beta[j] != 0.0 {
                            x.col_axpy(j, beta[j], &mut r);
                            beta[j] = 0.0;
                        }
                    }
                    keep
                });
                active.retain(|&j| !screened[j]);
            }
            if gap <= tol {
                converged = true;
                break;
            }
        }
    }
    LegacyOut { beta, r, theta: dualst.theta, gap, epochs, converged }
}

fn assert_solve_results_bitwise(label: &str, new: &SolveResult, old: &LegacyOut) {
    assert_eq!(new.epochs, old.epochs, "{label}: epoch count");
    assert_eq!(new.converged, old.converged, "{label}: converged");
    assert_eq!(new.gap.to_bits(), old.gap.to_bits(), "{label}: gap bits");
    assert_eq!(new.beta.len(), old.beta.len());
    for j in 0..new.beta.len() {
        assert_eq!(new.beta[j].to_bits(), old.beta[j].to_bits(), "{label}: beta[{j}]");
    }
    for i in 0..new.r.len() {
        assert_eq!(new.r[i].to_bits(), old.r[i].to_bits(), "{label}: r[{i}]");
    }
    for i in 0..new.theta.len() {
        assert_eq!(new.theta[i].to_bits(), old.theta[i].to_bits(), "{label}: theta[{i}]");
    }
}

/// Three-way pin: the legacy port, `cd_solve` (whose `P = L1` flows in
/// implicitly through `solve` → `solve_datafit` → `solve_penalty`), and
/// an explicit `engine::solve_penalty(.., &L1)` call must agree bit for
/// bit.
fn assert_engine_bitwise(x: &DesignMatrix, y: &[f64], ratio: f64, screen: bool, extrapolate: bool) {
    let lambda = dual::lambda_max(x, y) * ratio;
    let cfg = CdConfig {
        tol: 1e-9,
        max_epochs: 2_000,
        gap_freq: 10,
        k: 5,
        extrapolate,
        best_dual: true,
        screen,
        ..Default::default()
    };
    let old = legacy_cd_solve(
        x, y, lambda, cfg.tol, cfg.max_epochs, cfg.gap_freq, cfg.k, extrapolate, screen,
    );
    let new = cd_solve(x, y, lambda, None, &cfg);
    assert_solve_results_bitwise("cd_solve", &new, &old);
    let engine_cfg = EngineConfig {
        tol: cfg.tol,
        max_epochs: cfg.max_epochs,
        gap_freq: cfg.gap_freq,
        k: cfg.k,
        extrapolate,
        best_dual: true,
        screen,
        trace: false,
        stop: StopRule::DualityGap,
        ..EngineConfig::default()
    };
    let mut ws = Workspace::new();
    let outcome = engine::solve_penalty(
        x,
        y,
        lambda,
        Init::Zeros,
        None,
        &engine_cfg,
        &mut ws,
        &mut CdStrategy,
        &Quadratic,
        &L1,
    );
    let explicit = ws.solve_result(outcome);
    assert_solve_results_bitwise("solve_penalty(L1)", &explicit, &old);
}

#[test]
fn l1_engine_bitwise_matches_prepenalty_dense() {
    let ds = synth::leukemia_mini(300);
    for &(screen, extrap) in &[(false, true), (true, true), (false, false), (true, false)] {
        assert_engine_bitwise(&ds.x, &ds.y, 0.1, screen, extrap);
    }
}

#[test]
fn l1_engine_bitwise_matches_prepenalty_sparse() {
    let ds = synth::finance_mini(301);
    for &(screen, extrap) in &[(false, true), (true, true)] {
        assert_engine_bitwise(&ds.x, &ds.y, 0.2, screen, extrap);
    }
}

// ---------------------------------------------------------------------
// 1b. CELER outer-loop pin: P = L1 vs the pre-penalty outer loop
// ---------------------------------------------------------------------

struct LegacyCelerIter {
    gap: f64,
    ws_size: usize,
    support_size: usize,
    inner_epochs: usize,
    dual_winner: usize,
}

struct LegacyCelerOut {
    beta: Vec<f64>,
    r: Vec<f64>,
    theta: Vec<f64>,
    gap: f64,
    epochs: usize,
    converged: bool,
    iters: Vec<LegacyCelerIter>,
}

/// Faithful port of the pre-penalty CELER outer loop (Algorithm 4 with
/// pruning, stagnation safeguard, fused Eq. 4 rescale and Eq. 13
/// argmax-of-three) exactly as `celer_solve_penalty`'s `P = L1` arms
/// hardcoded it before penalties existed. The inner solves reuse the
/// engine pinned in section 1a, as the original did.
fn legacy_celer_solve(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    cfg: &CelerConfig,
) -> LegacyCelerOut {
    let n = x.n();
    let p = x.p();

    // init_primal_datafit (quadratic): cached norms, β = 0, r = y − Xβ
    let mut norms_sq = vec![0.0; p];
    for (j, v) in norms_sq.iter_mut().enumerate() {
        *v = x.col_norm_sq(j);
    }
    let col_norms: Vec<f64> = norms_sq.iter().map(|v| v.sqrt()).collect();
    let mut beta = vec![0.0; p];
    let mut xw = vec![0.0; n];
    let mut r = vec![0.0; n];
    primal::glm_state(x, &Quadratic, y, &beta, &mut xw, &mut r);
    let cache = Quadratic.conj_cache(y);

    // θ⁰ = θ⁰_inner = r(0) / ‖Xᵀr(0)‖_∞
    let mut r0_buf = Vec::new();
    let r0 = Quadratic.residual_at_zero(y, &mut r0_buf);
    let lmax = x.xt_abs_max(r0).max(f64::MIN_POSITIVE);
    let mut theta: Vec<f64> = r0.iter().map(|&v| v / lmax).collect();
    let mut theta_inner = theta.clone();
    let mut theta_res = vec![0.0; n];

    let mut policy = cfg.ws;
    let s0 = primal::support_size(&beta);
    if s0 > 0 {
        policy.p1 = s0;
    }

    let mut scratch = DualScratch::default();
    scratch.prepare(n, p);
    let mut xtheta = vec![0.0; p];
    let mut xtheta_inner = vec![0.0; p];
    x.xt_vec(&theta_inner, &mut xtheta_inner);
    let mut d_scores = vec![0.0; p];

    let mut inner_ws = Workspace::new();
    let mut prev_ws: Vec<usize> = primal::support(&beta);
    let mut prev_ws_size = 0usize;
    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut total_inner_epochs = 0usize;
    let mut iters: Vec<LegacyCelerIter> = Vec::new();

    let mut prev_gap = f64::INFINITY;
    for t in 1..=cfg.max_outer {
        // ---- θ^t = argmax D over {θ^{t-1}, θ_inner^{t-1}, θ_res^t} ----
        let denom = dual::glm_rescale_to_feasible_into(
            x,
            &r,
            lambda,
            &Quadratic,
            &mut scratch.xtr,
            &mut theta_res,
        );
        let winner = dual::glm_best_dual_point(
            &Quadratic,
            y,
            lambda,
            cache,
            &[&theta, &theta_inner, &theta_res],
        );
        match winner {
            1 => theta.copy_from_slice(&theta_inner),
            2 => theta.copy_from_slice(&theta_res),
            _ => {}
        }
        let rank_winner =
            dual::glm_best_dual_point(&Quadratic, y, lambda, cache, &[&theta_inner, &theta_res]);
        if rank_winner == 1 {
            for (o, &v) in xtheta.iter_mut().zip(scratch.xtr.iter()) {
                *o = v / denom;
            }
        } else {
            xtheta.copy_from_slice(&xtheta_inner);
        }

        // ---- global gap / stop ----
        let p_val = primal::glm_primal_value(&Quadratic, y, &xw, &r, &beta, lambda);
        gap = p_val - Quadratic.dual(y, &theta, lambda, cache);
        let support = primal::support(&beta);
        if gap <= cfg.tol {
            converged = true;
            iters.push(LegacyCelerIter {
                gap,
                ws_size: 0,
                support_size: support.len(),
                inner_epochs: 0,
                dual_winner: winner,
            });
            break;
        }

        // ---- working set ----
        celer::screening::fill_d_scores(&xtheta, &col_norms, &mut d_scores);
        let stagnated = t >= 2 && gap > 0.9 * prev_gap;
        prev_gap = gap;
        let forced_vec: Vec<usize>;
        let forced: &[usize] = if policy.prune && !stagnated {
            &support
        } else if policy.prune {
            forced_vec = {
                let mut f = prev_ws.clone();
                f.extend(support.iter().copied());
                f.sort_unstable();
                f.dedup();
                f
            };
            &forced_vec
        } else {
            &prev_ws
        };
        let mut pt = policy.next_size(t, prev_ws_size, support.len(), p);
        if stagnated {
            pt = pt.max((2 * prev_ws_size).min(p));
        }
        let pt = pt.max(forced.len());
        let ws_idx = build_working_set(&mut d_scores, forced, pt);

        // ---- inner solve on a zero-copy view of X_{W_t} ----
        let eps_t = if policy.prune { cfg.inner_tol_ratio * gap } else { cfg.tol };
        let beta_ws: Vec<f64> = ws_idx.iter().map(|&j| beta[j]).collect();
        let inner_cfg = EngineConfig {
            tol: eps_t,
            max_epochs: cfg.max_inner_epochs,
            gap_freq: cfg.gap_freq,
            k: cfg.k,
            extrapolate: cfg.extrapolate,
            best_dual: true,
            screen: false,
            trace: false,
            stop: StopRule::DualityGap,
            ..EngineConfig::default()
        };
        let inner_epochs = {
            let view = DesignView::new(x, &ws_idx, &norms_sq);
            let outcome = engine::solve(
                &view,
                y,
                lambda,
                Init::Warm(&beta_ws),
                None,
                &inner_cfg,
                &mut inner_ws,
                &mut CdStrategy,
            );
            outcome.epochs
        };
        total_inner_epochs += inner_epochs;

        // ---- lift the subproblem solution back ----
        beta.fill(0.0);
        for (i, &j) in ws_idx.iter().enumerate() {
            beta[j] = inner_ws.beta[i];
        }
        r.copy_from_slice(&inner_ws.r);
        xw.copy_from_slice(&inner_ws.xw);

        let s = x.xt_vec_abs_max(&inner_ws.dual.theta, &mut xtheta_inner).max(1.0);
        let inv_s = 1.0 / s;
        theta_inner.clear();
        theta_inner.extend(inner_ws.dual.theta.iter().map(|&v| v * inv_s));
        for v in xtheta_inner.iter_mut() {
            *v *= inv_s;
        }

        iters.push(LegacyCelerIter {
            gap,
            ws_size: ws_idx.len(),
            support_size: support.len(),
            inner_epochs,
            dual_winner: winner,
        });
        prev_ws_size = ws_idx.len();
        prev_ws = ws_idx;
    }
    LegacyCelerOut { beta, r, theta, gap, epochs: total_inner_epochs, converged, iters }
}

/// Pin `celer_solve_on` (implicit `P = L1`) AND the explicit
/// `celer_penalty_solve_on_ws(.., &L1, ..)` entry against the legacy
/// port — totals, final state, and every outer iteration's record.
fn assert_celer_bitwise(x: &DesignMatrix, y: &[f64], ratio: f64, cfg: &CelerConfig) {
    let lambda = dual::lambda_max(x, y) * ratio;
    let old = legacy_celer_solve(x, y, lambda, cfg);
    let new = celer_solve_on(x, y, lambda, None, cfg);
    let mut ws = Workspace::new();
    let explicit = celer_penalty_solve_on_ws(x, y, lambda, None, &L1, cfg, &mut ws);
    for (label, out) in
        [("celer_solve_on", &new), ("celer_penalty_solve_on_ws(L1)", &explicit)]
    {
        assert_eq!(out.result.epochs, old.epochs, "{label}: total inner epochs");
        assert_eq!(out.result.converged, old.converged, "{label}: converged");
        assert_eq!(out.result.gap.to_bits(), old.gap.to_bits(), "{label}: gap bits");
        for j in 0..old.beta.len() {
            assert_eq!(out.result.beta[j].to_bits(), old.beta[j].to_bits(), "{label}: beta[{j}]");
        }
        for i in 0..old.r.len() {
            assert_eq!(out.result.r[i].to_bits(), old.r[i].to_bits(), "{label}: r[{i}]");
        }
        for i in 0..old.theta.len() {
            assert_eq!(out.result.theta[i].to_bits(), old.theta[i].to_bits(), "{label}: theta[{i}]");
        }
        assert_eq!(out.iterations.len(), old.iters.len(), "{label}: outer iteration count");
        for (it, leg) in out.iterations.iter().zip(&old.iters) {
            let t = it.t;
            assert_eq!(it.gap.to_bits(), leg.gap.to_bits(), "{label}: t={t} gap");
            assert_eq!(it.ws_size, leg.ws_size, "{label}: t={t} ws_size");
            assert_eq!(it.support_size, leg.support_size, "{label}: t={t} support");
            assert_eq!(it.inner_epochs, leg.inner_epochs, "{label}: t={t} inner epochs");
            assert_eq!(it.dual_winner, leg.dual_winner, "{label}: t={t} dual winner");
        }
    }
}

#[test]
fn l1_celer_bitwise_matches_prepenalty_dense() {
    let ds = synth::leukemia_mini(302);
    assert_celer_bitwise(&ds.x, &ds.y, 0.1, &CelerConfig { tol: 1e-8, ..Default::default() });
    assert_celer_bitwise(&ds.x, &ds.y, 0.1, &CelerConfig { tol: 1e-8, ..CelerConfig::safe() });
}

#[test]
fn l1_celer_bitwise_matches_prepenalty_sparse() {
    let ds = synth::finance_mini(303);
    assert_celer_bitwise(&ds.x, &ds.y, 0.2, &CelerConfig { tol: 1e-8, ..Default::default() });
}

// ---------------------------------------------------------------------
// 2. penalty conformance suite (every impl, dense + CSC)
// ---------------------------------------------------------------------

fn engine_cfg(tol: f64, screen: bool) -> EngineConfig {
    EngineConfig {
        tol,
        max_epochs: 100_000,
        gap_freq: 10,
        k: 5,
        extrapolate: true,
        best_dual: true,
        screen,
        trace: false,
        stop: StopRule::DualityGap,
        ..EngineConfig::default()
    }
}

fn solve_pen<P: Penalty>(
    x: &DesignMatrix,
    y: &[f64],
    lambda: f64,
    pen: &P,
    tol: f64,
    screen: bool,
) -> (SolveResult, Workspace) {
    let mut ws = Workspace::new();
    let outcome = engine::solve_penalty(
        x,
        y,
        lambda,
        Init::Zeros,
        None,
        &engine_cfg(tol, screen),
        &mut ws,
        &mut CdStrategy,
        &Quadratic,
        pen,
    );
    let res = ws.solve_result(outcome);
    (res, ws)
}

/// `b = prox_{λΩ/nrm}(u)` must minimize `h(c) = ½·nrm·‖c−u‖² + Ω_λ(c)`:
/// no coordinate nudge, rescale, zeroing or reversion to `u` may beat
/// it. For separable penalties the prox fixed point must also be a
/// zero of the KKT residual `subdiff_distance(j, nrm·(u_j−b_j), b_j)`.
fn check_prox_optimality<P: Penalty>(pen: &P, p: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let h = |c: &[f64], u: &[f64], lambda: f64, nrm: f64| -> f64 {
        let mut q = 0.0;
        for (ci, ui) in c.iter().zip(u.iter()) {
            q += (ci - ui) * (ci - ui);
        }
        0.5 * nrm * q + pen.value(lambda, c)
    };
    for _ in 0..4 {
        let u: Vec<f64> = (0..p).map(|_| rng.normal() * 2.0).collect();
        let lambda = 0.3 + rng.uniform();
        let nrm = 0.5 + 2.0 * rng.uniform();
        let mut b = vec![0.0; p];
        pen.prox_vec(&u, lambda, nrm, &mut b);
        let hb = h(&b, &u, lambda, nrm);
        let check = |c: &[f64]| {
            let hc = h(c, &u, lambda, nrm);
            assert!(
                hb <= hc + 1e-9,
                "prox is not the minimizer: h(b) = {hb} > h(c) = {hc}"
            );
        };
        for j in 0..p {
            for delta in [-0.3, -1e-2, -1e-4, 1e-4, 1e-2, 0.3] {
                let mut c = b.clone();
                c[j] += delta;
                check(&c);
            }
            let mut c = b.clone();
            c[j] = 0.0;
            check(&c);
        }
        check(&u);
        check(&b.iter().map(|&v| 0.9 * v).collect::<Vec<_>>());
        check(&b.iter().map(|&v| 1.1 * v).collect::<Vec<_>>());
        check(&vec![0.0; p]);
        if P::SEPARABLE {
            for j in 0..p {
                let g = nrm * (u[j] - b[j]);
                let d = pen.subdiff_distance(j, g, b[j], lambda);
                assert!(d <= 1e-8, "prox/subdiff mismatch at j={j}: kkt residual {d}");
            }
        }
    }
}

#[test]
fn prox_minimizes_its_objective_for_every_penalty() {
    let p = 12;
    check_prox_optimality(&L1, p, 500);
    check_prox_optimality(&ElasticNet::new(0.5), p, 501);
    check_prox_optimality(&ElasticNet::new(0.9), p, 502);
    let mut w: Vec<f64> = (0..p).map(|j| 0.5 + 0.25 * j as f64).collect();
    w[3] = 0.0;
    w[7] = f64::INFINITY;
    check_prox_optimality(&WeightedL1::new(w), p, 503);
    check_prox_optimality(&GroupLasso::new(4), p, 504);
}

/// Indicator-dual penalties: any u with `Ω^D(u) ≤ λ` satisfies the
/// Fenchel inequality `⟨u, β⟩ ≤ λ·Ω(β)` for every β — `dual_norm` and
/// `value` must be consistent duals of one another.
fn check_fenchel_indicator<P: Penalty>(pen: &P, p: usize, seed: u64) {
    assert!(P::INDICATOR_DUAL);
    let mut rng = Rng::new(seed);
    for _ in 0..8 {
        let mut u: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        for (j, v) in u.iter_mut().enumerate() {
            if !pen.is_penalized(j) {
                *v = 0.0;
            }
        }
        let lambda = 0.2 + rng.uniform();
        let dn = pen.dual_norm(lambda, &u);
        if dn == 0.0 {
            continue;
        }
        let scale = lambda / dn * (1.0 - 1e-12);
        for v in u.iter_mut() {
            *v *= scale;
        }
        let beta: Vec<f64> = (0..p).map(|_| rng.normal() * 3.0).collect();
        let lhs = dot(&u, &beta);
        let rhs = pen.value(lambda, &beta);
        assert!(lhs <= rhs + 1e-9, "Fenchel violated: ⟨u,β⟩ = {lhs} > λΩ(β) = {rhs}");
    }
}

#[test]
fn dual_norm_and_value_are_fenchel_consistent() {
    let p = 16;
    check_fenchel_indicator(&L1, p, 510);
    let mut w: Vec<f64> = (0..p).map(|j| 0.4 + 0.2 * j as f64).collect();
    w[5] = 0.0;
    check_fenchel_indicator(&WeightedL1::new(w), p, 511);
    check_fenchel_indicator(&GroupLasso::new(4), p, 512);
}

#[test]
fn elastic_net_conjugate_matches_numeric_maximization() {
    // ω*(v) = max_b (v·b − α|b| − ½(1−α)b²), computed on a fine grid.
    let pen = ElasticNet::new(0.6);
    let a = 0.6;
    let lambda = 0.8;
    let mut rng = Rng::new(513);
    for _ in 0..12 {
        let v = rng.normal() * 2.0;
        let analytic = pen.conjugate(lambda, &[v], 1.0);
        let span = (v.abs() + 1.0) / (1.0 - a);
        let steps = 4000;
        let mut best = f64::NEG_INFINITY;
        for i in 0..=steps {
            let b = -span + 2.0 * span * i as f64 / steps as f64;
            best = best.max(v * b - a * b.abs() - 0.5 * (1.0 - a) * b * b);
        }
        let numeric = lambda * best.max(0.0);
        assert!(
            (analytic - numeric).abs() <= 1e-5 * (1.0 + numeric.abs()),
            "ω*({v}) analytic {analytic} vs numeric {numeric}"
        );
        // the scale parameter folds into the argument exactly
        assert_eq!(
            pen.conjugate(lambda, &[v], 2.0).to_bits(),
            pen.conjugate(lambda, &[2.0 * v], 1.0).to_bits()
        );
    }
}

#[test]
fn elastic_net_fenchel_young_holds_with_equality_at_the_subgradient() {
    let pen = ElasticNet::new(0.7);
    let a = 0.7;
    let lambda = 1.3;
    let mut rng = Rng::new(514);
    for _ in 0..8 {
        let beta: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        // arbitrary u: value + conjugate ≥ λ·⟨u, β⟩
        let u: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let slack = pen.value(lambda, &beta) + pen.conjugate(lambda, &u, 1.0)
            - lambda * dot(&u, &beta);
        assert!(slack >= -1e-10, "Fenchel–Young violated by {slack}");
        // u* ∈ ∂ω(β): equality up to roundoff
        let ustar: Vec<f64> =
            beta.iter().map(|&b| a * b.signum() + (1.0 - a) * b).collect();
        let tight = pen.value(lambda, &beta) + pen.conjugate(lambda, &ustar, 1.0)
            - lambda * dot(&ustar, &beta);
        assert!(tight.abs() <= 1e-9, "Fenchel–Young not tight at ∂ω(β): {tight}");
    }
}

#[test]
fn l1_penalty_lambda_max_is_bitwise_the_historical_lambda_max() {
    for ds in [synth::leukemia_mini(515), synth::finance_mini(516)] {
        assert_eq!(
            dual::penalty_lambda_max(&ds.x, &ds.y, &L1).to_bits(),
            dual::lambda_max(&ds.x, &ds.y).to_bits()
        );
    }
}

/// λ ≥ λ_max must certify β̂ = 0; λ = 0.8·λ_max must select features.
fn check_lambda_max<P: Penalty>(ds: &SynthDataset, pen: &P) {
    let lmax = dual::penalty_lambda_max(&ds.x, &ds.y, pen);
    assert!(lmax > 0.0);
    let (at, _) = solve_pen(&ds.x, &ds.y, lmax * 1.000_000_1, pen, 1e-10, false);
    assert!(at.converged, "{}: no certificate at λ_max", ds.name);
    assert_eq!(at.support_size(), 0, "{}: nonzero β̂ at λ ≥ λ_max", ds.name);
    let (below, _) = solve_pen(&ds.x, &ds.y, lmax * 0.8, pen, 1e-8, false);
    assert!(below.converged, "{}: below λ_max", ds.name);
    assert!(below.support_size() > 0, "{}: empty model below λ_max", ds.name);
}

#[test]
fn lambda_max_is_the_empty_model_threshold_for_every_penalty() {
    for ds in [synth::leukemia_mini(400), synth::finance_mini(401)] {
        let mut rng = Rng::new(4000);
        let w: Vec<f64> = (0..ds.x.p()).map(|_| 0.5 + 1.5 * rng.uniform()).collect();
        check_lambda_max(&ds, &L1);
        check_lambda_max(&ds, &ElasticNet::new(0.5));
        check_lambda_max(&ds, &WeightedL1::new(w));
        check_lambda_max(&ds, &GroupLasso::new(4));
    }
}

/// Gap Safe safety: every feature the screened run discards must be
/// zero in a tight unscreened reference, and screening must not move
/// the objective beyond the certification bound.
fn check_gap_safe_safety<P: Penalty>(ds: &SynthDataset, pen: &P) {
    let lambda = 0.25 * dual::penalty_lambda_max(&ds.x, &ds.y, pen);
    let tol = 1e-8;
    let (loose, ws) = solve_pen(&ds.x, &ds.y, lambda, pen, tol, true);
    let (tight, _) = solve_pen(&ds.x, &ds.y, lambda, pen, 1e-12, false);
    assert!(loose.converged && tight.converged, "{}", ds.name);
    assert!(ws.screening.n_screened() > 0, "{}: screening never fired", ds.name);
    for j in 0..ds.x.p() {
        if ws.screening.is_screened(j) {
            assert!(
                tight.beta[j].abs() <= 1e-8,
                "{}: screened feature {j} is active in the tight reference ({})",
                ds.name,
                tight.beta[j]
            );
        }
    }
    let obj = |res: &SolveResult| 0.5 * dot(&res.r, &res.r) + pen.value(lambda, &res.beta);
    let (ol, ot) = (obj(&loose), obj(&tight));
    assert!((ol - ot).abs() <= 2.0 * tol, "{}: {ol} vs {ot}", ds.name);
}

#[test]
fn gap_safe_screening_is_safe_for_every_penalty() {
    for ds in [synth::leukemia_mini(402), synth::finance_mini(403)] {
        let mut rng = Rng::new(4020);
        let w: Vec<f64> = (0..ds.x.p()).map(|_| 0.5 + 1.5 * rng.uniform()).collect();
        check_gap_safe_safety(&ds, &L1);
        check_gap_safe_safety(&ds, &ElasticNet::new(0.5));
        check_gap_safe_safety(&ds, &WeightedL1::new(w));
        check_gap_safe_safety(&ds, &GroupLasso::new(4));
    }
}

#[test]
fn celer_outer_loop_matches_engine_for_separable_penalties() {
    // the working-set path and the full-design engine agree on the
    // ε-certified objective for the non-ℓ₁ separable penalties
    let tol = 1e-9;
    for (ds, alpha) in [(synth::leukemia_mini(406), 0.5), (synth::finance_mini(407), 0.7)] {
        let pen = ElasticNet::new(alpha);
        let lambda = 0.3 * dual::penalty_lambda_max(&ds.x, &ds.y, &pen);
        let mut ws = Workspace::new();
        let cel = celer_penalty_solve_on_ws(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &pen,
            &CelerConfig { tol, ..Default::default() },
            &mut ws,
        );
        let (eng, _) = solve_pen(&ds.x, &ds.y, lambda, &pen, tol, false);
        assert!(cel.result.converged && eng.converged, "{}", ds.name);
        assert!(cel.result.gap <= tol && eng.gap <= tol);
        let obj = |beta: &[f64], r: &[f64]| 0.5 * dot(r, r) + pen.value(lambda, beta);
        let (oc, oe) = (obj(&cel.result.beta, &cel.result.r), obj(&eng.beta, &eng.r));
        assert!((oc - oe).abs() <= 2.0 * tol, "{}: {oc} vs {oe}", ds.name);
    }
    {
        let ds = synth::leukemia_mini(406);
        let mut rng = Rng::new(4060);
        let w: Vec<f64> = (0..ds.x.p()).map(|_| 0.5 + 1.5 * rng.uniform()).collect();
        let pen = WeightedL1::new(w);
        let lambda = 0.3 * dual::penalty_lambda_max(&ds.x, &ds.y, &pen);
        let mut ws = Workspace::new();
        let cel = celer_penalty_solve_on_ws(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &pen,
            &CelerConfig { tol, ..Default::default() },
            &mut ws,
        );
        let (eng, _) = solve_pen(&ds.x, &ds.y, lambda, &pen, tol, false);
        assert!(cel.result.converged && eng.converged);
        let obj = |beta: &[f64], r: &[f64]| 0.5 * dot(r, r) + pen.value(lambda, beta);
        let (oc, oe) = (obj(&cel.result.beta, &cel.result.r), obj(&eng.beta, &eng.r));
        assert!((oc - oe).abs() <= 2.0 * tol, "wlasso: {oc} vs {oe}");
    }
}

// ---------------------------------------------------------------------
// 3. elastic net ≡ Lasso on the augmented design [X; √(λ(1−α))·I]
// ---------------------------------------------------------------------

/// See `prop_batch_path.rs`: two ε-certified solutions agree on the
/// support only at their own agreement resolution.
fn assert_same_support(beta_s: &[f64], beta_b: &[f64], what: &str) {
    let delta = beta_s
        .iter()
        .zip(beta_b.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(delta <= 1e-3, "{what}: solutions diverge coefficientwise ({delta})");
    let thr = (10.0 * delta).max(1e-9);
    let sup = |beta: &[f64]| -> Vec<usize> {
        beta.iter()
            .enumerate()
            .filter(|(_, &v)| v.abs() > thr)
            .map(|(j, _)| j)
            .collect()
    };
    assert_eq!(sup(beta_s), sup(beta_b), "{what}: supports differ (thr {thr:.1e})");
}

#[test]
fn elastic_net_equals_lasso_on_the_augmented_design() {
    let ds = synth::leukemia_mini(404);
    let (n, p) = (ds.x.n(), ds.x.p());
    let tol = 1e-10;
    for alpha in [0.5, 0.8] {
        let pen = ElasticNet::new(alpha);
        let lambda = 0.3 * dual::penalty_lambda_max(&ds.x, &ds.y, &pen);
        let mut ws = Workspace::new();
        let en = celer_penalty_solve_on_ws(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &pen,
            &CelerConfig { tol, ..Default::default() },
            &mut ws,
        );
        assert!(en.result.converged, "α={alpha}: EN gap {}", en.result.gap);

        // augmented design: column j is [x_j; √(λ(1−α))·e_j]
        let ridge = (lambda * (1.0 - alpha)).sqrt();
        let mut xcols = Vec::new();
        let all: Vec<usize> = (0..p).collect();
        ds.x.gather_dense(&all, &mut xcols);
        let n_aug = n + p;
        let mut aug = vec![0.0; n_aug * p];
        for j in 0..p {
            aug[j * n_aug..j * n_aug + n].copy_from_slice(&xcols[j * n..(j + 1) * n]);
            aug[j * n_aug + n + j] = ridge;
        }
        let x_aug = DesignMatrix::Dense(DenseMatrix::from_col_major(n_aug, p, aug));
        let mut y_aug = vec![0.0; n_aug];
        y_aug[..n].copy_from_slice(&ds.y);
        let lasso = cd_solve(
            &x_aug,
            &y_aug,
            lambda * alpha,
            None,
            &CdConfig { tol, ..Default::default() },
        );
        assert!(lasso.converged, "α={alpha}: augmented Lasso gap {}", lasso.gap);

        // both certify the SAME objective: the augmented Lasso primal
        // at λα is exactly the elastic-net primal on the original X
        let en_obj = |beta: &[f64]| {
            let mut r = vec![0.0; n];
            primal::residual(&ds.x, &ds.y, beta, &mut r);
            0.5 * dot(&r, &r) + pen.value(lambda, beta)
        };
        let o_en = en_obj(&en.result.beta);
        let o_aug = en_obj(&lasso.beta);
        assert!(
            (o_en - o_aug).abs() <= 2.0 * tol + 1e-12,
            "α={alpha}: EN objective {o_en} vs augmented {o_aug}"
        );
        assert_same_support(&en.result.beta, &lasso.beta, &format!("α={alpha}"));
    }
}

// ---------------------------------------------------------------------
// 4. weighted-ℓ₁ edge weights: w = 0 and w = ∞
// ---------------------------------------------------------------------

#[test]
fn weighted_l1_zero_weight_is_never_screened_and_infinite_weight_is_zero() {
    let ds = synth::leukemia_mini(405);
    let p = ds.x.p();
    let mut w = vec![1.0; p];
    w[0] = 0.0; // unpenalized: free coefficient, never screened
    w[1] = f64::INFINITY; // hard-zeroed: exactly 0, screened out
    let pen = WeightedL1::new(w);
    let lambda = 0.3 * dual::penalty_lambda_max(&ds.x, &ds.y, &pen);
    let tol = 1e-9;
    let (res, ws) = solve_pen(&ds.x, &ds.y, lambda, &pen, tol, true);
    assert!(res.converged, "gap {}", res.gap);
    assert!(!ws.screening.is_screened(0), "w = 0 feature was screened");
    assert!(ws.screening.n_screened() > 0, "screening never fired");
    assert_eq!(res.beta[1], 0.0, "w = ∞ feature must be exactly zero");
    assert!(res.beta[0] != 0.0, "w = 0 feature should enter freely");
    // unpenalized ⇒ the KKT condition is x_0ᵀr = 0 (lenient: the dual
    // value ignores the w = 0 conjugate, so the gap slightly understates
    // suboptimality near the optimum)
    assert!(ds.x.col_dot(0, &res.r).abs() < 1e-3, "x_0ᵀr = {}", ds.x.col_dot(0, &res.r));

    // same story through the CELER working-set path
    let mut ws2 = Workspace::new();
    let cel = celer_penalty_solve_on_ws(
        &ds.x,
        &ds.y,
        lambda,
        None,
        &pen,
        &CelerConfig { tol, ..Default::default() },
        &mut ws2,
    );
    assert!(cel.result.converged);
    assert_eq!(cel.result.beta[1], 0.0);
    assert!(cel.result.beta[0] != 0.0);
    let obj = |beta: &[f64], r: &[f64]| 0.5 * dot(r, r) + pen.value(lambda, beta);
    let (oc, oe) = (obj(&cel.result.beta, &cel.result.r), obj(&res.beta, &res.r));
    assert!((oc - oe).abs() <= 2.0 * tol, "celer {oc} vs engine {oe}");
}
