//! λ-path integration: warm starts, grids, and cross-solver agreement
//! along entire paths (the §6.3 setting).

use celer::coordinator::{self, PathJob};
use celer::data::synth;
use celer::lasso::{dual, primal};
use celer::solvers::path::{lambda_grid, run_path, PathSolver};

#[test]
fn warm_path_matches_cold_solves() {
    let ds = synth::leukemia_mini(120);
    let lmax = dual::lambda_max(&ds.x, &ds.y);
    let grid = lambda_grid(lmax * 0.9, 0.05, 6);
    let solver = PathSolver::by_name("celer-prune", 1e-9).unwrap();
    let path = run_path(&ds.x, &ds.y, &grid, &solver, true);
    assert!(path.all_converged());
    for (i, &lambda) in grid.iter().enumerate() {
        let cold = celer::solvers::cd::cd_solve(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &celer::solvers::cd::CdConfig { tol: 1e-11, ..Default::default() },
        );
        let p_cold = primal::primal(&ds.x, &ds.y, &cold.beta, lambda);
        let p_path =
            primal::primal(&ds.x, &ds.y, path.steps[i].beta.as_ref().unwrap(), lambda);
        assert!(
            (p_path - p_cold).abs() < 1e-7,
            "λ#{i}: warm {p_path} vs cold {p_cold}"
        );
    }
}

#[test]
fn all_path_solvers_reach_tolerance_on_sparse() {
    let ds = synth::finance_mini(121);
    let grid = coordinator::standard_grid(&ds, 50.0, 8);
    for name in ["celer-prune", "celer-safe", "blitz", "gapsafe-cd-accel", "gapsafe-cd-res"] {
        let solver = PathSolver::by_name(name, 1e-6).unwrap();
        let res = run_path(&ds.x, &ds.y, &grid, &solver, false);
        assert!(res.all_converged(), "{name} failed on the sparse path");
        for s in &res.steps {
            assert!(s.gap <= 1e-6, "{name}: gap {} at λ={}", s.gap, s.lambda);
        }
    }
}

#[test]
fn glmnet_path_runs_and_support_grows() {
    let ds = synth::leukemia_mini(122);
    let grid = coordinator::standard_grid(&ds, 100.0, 10);
    let solver = PathSolver::by_name("glmnet", 1e-8).unwrap();
    let res = run_path(&ds.x, &ds.y, &grid, &solver, false);
    let first = res.steps.first().unwrap().support_size;
    let last = res.steps.last().unwrap().support_size;
    assert!(last > first);
}

#[test]
fn coordinator_parallel_equals_serial() {
    let ds = synth::leukemia_mini(123);
    let grid = coordinator::standard_grid(&ds, 20.0, 5);
    let jobs: Vec<PathJob> = ["celer-prune", "celer-safe", "blitz", "cd-vanilla"]
        .iter()
        .map(|s| PathJob {
            solver_name: s.to_string(),
            tol: 1e-7,
            grid: grid.clone(),
            store_betas: true,
        })
        .collect();
    let par = coordinator::run_path_jobs(&ds, jobs.clone(), 4).unwrap();
    let ser = coordinator::run_path_jobs(&ds, jobs, 1).unwrap();
    for (a, b) in par.iter().zip(&ser) {
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.beta, sb.beta, "{} must be order-independent", a.solver);
        }
    }
}

#[test]
fn warm_start_reduces_total_epochs() {
    // path vs repeated cold solves: warm starting must save inner epochs
    let ds = synth::leukemia_mini(124);
    let grid = coordinator::standard_grid(&ds, 50.0, 8);
    let solver = PathSolver::by_name("celer-prune", 1e-8).unwrap();
    let warm = run_path(&ds.x, &ds.y, &grid, &solver, false);
    let warm_epochs: usize = warm.steps.iter().map(|s| s.epochs).sum();
    let mut cold_epochs = 0;
    for &lambda in &grid {
        let out = celer::solvers::celer::celer_solve_on(
            &ds.x,
            &ds.y,
            lambda,
            None,
            &celer::solvers::celer::CelerConfig { tol: 1e-8, ..Default::default() },
        );
        cold_epochs += out.result.epochs;
    }
    // warm starting can tie on easy grids (both converge at the first
    // gap check per λ) but must never lose
    assert!(
        warm_epochs <= cold_epochs,
        "warm {warm_epochs} must not exceed cold {cold_epochs}"
    );
}

#[test]
fn grid_endpoints_behave() {
    let ds = synth::leukemia_mini(125);
    let lmax = dual::lambda_max(&ds.x, &ds.y);
    let grid = lambda_grid(lmax, 0.01, 5);
    let solver = PathSolver::by_name("celer-prune", 1e-8).unwrap();
    let res = run_path(&ds.x, &ds.y, &grid, &solver, false);
    // at λ = λ_max the solution is empty
    assert_eq!(res.steps[0].support_size, 0);
    // at λ_max/100 it is substantially populated
    assert!(res.steps[4].support_size > 5);
}
