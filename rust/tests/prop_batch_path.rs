//! Property tests for the batched multi-λ engine: a batched path must be
//! numerically equivalent to the sequential warm-started path —
//!
//! 1. every grid point is gap-certified at the same ε (the reported gap
//!    is ≤ tol and the objectives of the two schedules agree within the
//!    2·ε bound weak duality guarantees);
//! 2. the recovered supports are identical at every grid point;
//! 3. this holds on dense AND sparse designs, for B = 1 (the degenerate
//!    sequential schedule), a mid-size B, and B > grid-size.

use celer::data::synth::{self, SynthDataset};
use celer::lasso::{dual, primal};
use celer::solvers::path::{lambda_grid, lasso_path, run_path, PathResult, PathSolver};

fn sequential_reference(ds: &SynthDataset, grid: &[f64], tol: f64) -> PathResult {
    let solver = PathSolver::by_name("gapsafe-cd-accel", tol).unwrap();
    run_path(&ds.x, &ds.y, grid, &solver, true)
}

/// Assert the two ε-certified solutions carry the same support.
///
/// Two solutions with gap ≤ ε agree coefficientwise only up to the
/// certification resolution (‖X·Δβ‖ ≤ 2√(2ε)), so a raw nonzero-bit
/// comparison is a knife edge: a feature at the optimality boundary can
/// be exactly 0.0 in one schedule and O(Δ) in the other. Compare at the
/// solutions' own agreement resolution instead: any coefficient within
/// 10× the observed max deviation of zero counts as zero on both sides.
fn assert_same_support(beta_s: &[f64], beta_b: &[f64], what: &str) {
    let delta = beta_s
        .iter()
        .zip(beta_b.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(delta <= 1e-3, "{what}: solutions diverge coefficientwise ({delta})");
    let thr = (10.0 * delta).max(1e-9);
    let sup = |beta: &[f64]| -> Vec<usize> {
        beta.iter()
            .enumerate()
            .filter(|(_, &v)| v.abs() > thr)
            .map(|(j, _)| j)
            .collect()
    };
    assert_eq!(sup(beta_s), sup(beta_b), "{what}: supports differ (thr {thr:.1e})");
}

fn check_batched_equivalent(ds: &SynthDataset, grid: &[f64], tol: f64, lanes: usize) {
    let seq = sequential_reference(ds, grid, tol);
    let bat = lasso_path(&ds.x, &ds.y, grid, tol, lanes, true, &celer::penalty::L1);
    assert_eq!(bat.steps.len(), grid.len(), "one step per grid point");
    assert!(seq.all_converged(), "sequential reference converged");
    assert!(bat.all_converged(), "batched path converged (B = {lanes})");
    for (i, (ss, sb)) in seq.steps.iter().zip(&bat.steps).enumerate() {
        assert!((sb.lambda - grid[i]).abs() <= 1e-15 * grid[i].abs(), "grid order");
        // 1. gap certification at every grid point
        assert!(
            sb.gap <= tol,
            "B={lanes} λ#{i}: reported gap {} > tol {tol}",
            sb.gap
        );
        let beta_s = ss.beta.as_ref().unwrap();
        let beta_b = sb.beta.as_ref().unwrap();
        let ps = primal::primal(&ds.x, &ds.y, beta_s, grid[i]);
        let pb = primal::primal(&ds.x, &ds.y, beta_b, grid[i]);
        assert!(
            (ps - pb).abs() <= 2.0 * tol,
            "B={lanes} λ#{i}: objectives {ps} vs {pb} differ by more than 2ε"
        );
        // 2. identical supports (at the ε-certification resolution)
        assert_same_support(beta_s, beta_b, &format!("B={lanes} λ#{i}"));
    }
}

fn grid_for(ds: &SynthDataset, num: usize, min_ratio: f64) -> Vec<f64> {
    lambda_grid(dual::lambda_max(&ds.x, &ds.y), min_ratio, num)
}

#[test]
fn dense_batched_path_equals_sequential() {
    let ds = synth::leukemia_mini(101);
    let grid = grid_for(&ds, 8, 0.08);
    check_batched_equivalent(&ds, &grid, 1e-10, 4);
}

#[test]
fn sparse_batched_path_equals_sequential() {
    let ds = synth::finance_mini(102);
    let grid = grid_for(&ds, 6, 0.1);
    check_batched_equivalent(&ds, &grid, 1e-10, 3);
}

#[test]
fn degenerate_single_lane_equals_sequential() {
    // B = 1: lanes never overlap, so the schedule is exactly the
    // sequential warm-started chain.
    let ds = synth::leukemia_mini(103);
    let grid = grid_for(&ds, 5, 0.1);
    check_batched_equivalent(&ds, &grid, 1e-10, 1);
}

#[test]
fn more_lanes_than_grid_points_is_clamped() {
    // B > |grid|: every grid cell gets a lane immediately; no warm-start
    // chaining is possible, yet every point must still gap-certify.
    let ds = synth::leukemia_mini(104);
    let grid = grid_for(&ds, 4, 0.15);
    check_batched_equivalent(&ds, &grid, 1e-10, 16);
}

#[test]
fn batched_path_certifies_on_sparse_wide_lanes() {
    let ds = synth::finance_mini(105);
    let grid = grid_for(&ds, 5, 0.2);
    check_batched_equivalent(&ds, &grid, 1e-9, 8);
}

#[test]
fn batched_workspace_reuse_across_jobs_is_invariant() {
    // The coordinator reuses one Workspace (and its nested lane
    // workspace) across jobs; a dirty workspace must not change results.
    use celer::solvers::batch::BatchConfig;
    use celer::solvers::engine::Workspace;
    use celer::solvers::path::run_path_batched;
    let ds = synth::leukemia_mini(106);
    let grid = grid_for(&ds, 6, 0.1);
    let cfg = BatchConfig { tol: 1e-9, lanes: 3, ..Default::default() };
    let mut ws = Workspace::new();
    let first = run_path_batched(&ds.x, &ds.y, &grid, &cfg, true, &mut ws);
    // dirty with a different grid + lane count, then repeat the original
    let other = grid_for(&ds, 3, 0.5);
    let dirty_cfg = BatchConfig { tol: 1e-6, lanes: 2, ..Default::default() };
    let _ = run_path_batched(&ds.x, &ds.y, &other, &dirty_cfg, false, &mut ws);
    let again = run_path_batched(&ds.x, &ds.y, &grid, &cfg, true, &mut ws);
    assert_eq!(first.steps.len(), again.steps.len());
    for (a, b) in first.steps.iter().zip(&again.steps) {
        assert_eq!(a.epochs, b.epochs);
        assert_eq!(a.beta, b.beta);
    }
}
