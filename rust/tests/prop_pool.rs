//! Property tests for the persistent sharded worker pool and the
//! deterministic parallel primitives built on it (`util::pool` /
//! `util::par`), plus the fused design-scan kernels.
//!
//! The contracts pinned here:
//!
//! 1. **Determinism**: `par_sum`/`par_max`/`par_fill_abs_max` decompose
//!    work over a fixed shard grid (`par::SHARDS`) and fold partials in
//!    shard order, so their results are bit-identical for any
//!    `CELER_NUM_THREADS` (CI runs this suite at 1 and 4 threads) and
//!    identical to the in-process serial path (`par::run_serial`).
//! 2. **Fusion**: the fused kernels (`xt_vec_abs_max`, the fused KKT
//!    scan) equal their separate-pass counterparts bit-for-bit on dense
//!    and CSC designs.
//! 3. **Edge shapes**: empty inputs, p smaller than the shard count,
//!    and reentrancy from coordinator worker threads (which run in a
//!    serial scope and must produce the same bits).

use celer::coordinator::scheduler::run_parallel;
use celer::data::design::{DesignMatrix, DesignOps};
use celer::data::synth;
use celer::lasso::kkt;
use celer::util::par;
use celer::util::rng::Rng;

/// A dense design whose full-p scan clears the work-based parallel
/// threshold: p × n = 8192 × 64 = 2¹⁹ ≥ `PAR_WORK_THRESHOLD`.
fn big_dense(seed: u64) -> DesignMatrix {
    synth::dense_scan_stress(seed).x
}

/// A CSC design whose scan clears the threshold under the *sparse* cost
/// model: p × mean-nnz ≈ 32768 × 13 ≈ 4·10⁵ ≥ `PAR_WORK_THRESHOLD`.
fn big_sparse(seed: u64) -> DesignMatrix {
    synth::sparse_scan_stress(seed).x
}

fn rand_vec(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

#[test]
fn reductions_match_fixed_shard_fold_reference() {
    // Reference computed with the documented contract: fixed shard
    // grid, per-shard accumulation in index order, shard-order fold.
    let n = par::PAR_WORK_THRESHOLD + 4321;
    let f = |i: usize| ((i * 2654435761) % 997) as f64 * 1e-3 - 0.25;
    let chunk = n.div_ceil(par::SHARDS).max(1);
    let mut sum_ref = 0.0f64;
    let mut max_ref = f64::NEG_INFINITY;
    for s in 0..par::SHARDS {
        let (lo, hi) = ((s * chunk).min(n), ((s + 1) * chunk).min(n));
        let mut acc = 0.0;
        let mut m = f64::NEG_INFINITY;
        for i in lo..hi {
            acc += f(i);
            m = m.max(f(i));
        }
        sum_ref += acc;
        max_ref = max_ref.max(m);
    }
    assert_eq!(par::par_sum(n, f).to_bits(), sum_ref.to_bits());
    assert_eq!(par::par_max(n, f).to_bits(), max_ref.to_bits());
    // and the serial scope reproduces the same bits
    let serial = par::run_serial(|| par::par_sum(n, f));
    assert_eq!(serial.to_bits(), sum_ref.to_bits());
}

#[test]
fn empty_and_tiny_inputs() {
    assert_eq!(par::par_sum(0, |i| i as f64), 0.0);
    assert_eq!(par::par_max(0, |i| i as f64), f64::NEG_INFINITY);
    let mut out: Vec<f64> = Vec::new();
    par::par_fill(&mut out, |i| i as f64);
    assert!(out.is_empty());
    assert_eq!(par::par_fill_abs_max(&mut out, 1, |i| i as f64), 0.0);
    // fewer items than shards: every index still filled exactly once
    let mut small = vec![0.0; 5];
    par::par_fill(&mut small, |i| (i + 1) as f64);
    assert_eq!(small, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    assert_eq!(par::par_sum(5, |i| (i + 1) as f64), 15.0);
}

#[test]
fn pooled_design_scans_match_serial_bitwise() {
    for x in [&big_dense(7), &big_sparse(7)] {
        let v = rand_vec(8, x.n());
        let p = x.p();
        let mut pooled = vec![0.0; p];
        x.xt_vec(&v, &mut pooled);
        let (serial, serial_max, serial_norms) = par::run_serial(|| {
            let mut out = vec![0.0; p];
            x.xt_vec(&v, &mut out);
            (out, x.xt_abs_max(&v), x.col_norms_sq())
        });
        assert_eq!(pooled, serial, "xt_vec pooled == serial");
        assert_eq!(x.xt_abs_max(&v).to_bits(), serial_max.to_bits());
        assert_eq!(x.col_norms_sq(), serial_norms);
        // per-column oracle: each entry is one col_dot, bit-for-bit
        for j in 0..p {
            assert_eq!(pooled[j].to_bits(), x.col_dot(j, &v).to_bits(), "j={j}");
        }
    }
}

#[test]
fn fused_kernels_match_separate_passes() {
    for x in [&big_dense(9), &big_sparse(9)] {
        let v = rand_vec(10, x.n());
        let p = x.p();
        let mut fused = vec![0.0; p];
        let m = x.xt_vec_abs_max(&v, &mut fused);
        let mut plain = vec![0.0; p];
        x.xt_vec(&v, &mut plain);
        assert_eq!(fused, plain, "fused fill == xt_vec");
        let expect = plain.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        assert_eq!(m.to_bits(), expect.to_bits(), "fused max == separate scan");

        // fused KKT scan vs violations + max_violation
        let mut beta = vec![0.0; p];
        beta[3] = 0.7;
        beta[p - 1] = -0.2;
        let lambda = 0.5 * m;
        let mut kv = Vec::new();
        let kmax = kkt::violations_with_max(x, &v, &beta, lambda, &mut kv);
        assert_eq!(kv, kkt::violations(x, &v, &beta, lambda));
        assert_eq!(kmax.to_bits(), kkt::max_violation(x, &v, &beta, lambda).to_bits());
        let from_fused: Vec<usize> =
            kv.iter().enumerate().filter(|&(_, &w)| w > 1e-9).map(|(j, _)| j).collect();
        assert_eq!(kkt::violating_features(x, &v, &beta, lambda, 1e-9), from_fused);
    }
}

#[test]
fn reentrancy_from_coordinator_workers() {
    // Coordinator grid workers run in a serial scope; pool primitives
    // called from them must degrade gracefully AND return the exact
    // bits the pooled path returns.
    for x in [&big_dense(11), &big_sparse(11)] {
        let v = rand_vec(12, x.n());
        let p = x.p();
        let mut direct = vec![0.0; p];
        let direct_max = x.xt_vec_abs_max(&v, &mut direct);
        let jobs: Vec<usize> = (0..4).collect();
        let from_workers = run_parallel(jobs, 4, |_| {
            let mut out = vec![0.0; p];
            let m = x.xt_vec_abs_max(&v, &mut out);
            (out, m)
        });
        for (out, m) in from_workers {
            assert_eq!(out, direct, "worker-thread scan == direct scan");
            assert_eq!(m.to_bits(), direct_max.to_bits());
        }
    }
}

#[test]
fn first_touch_shadows_match_serial_construction_bitwise() {
    // The f32 shadows are built with `par::alloc_first_touch`: each
    // worker writes (first-touches) the shards it owns, so the pages
    // land on the worker's NUMA node. Placement must be invisible to
    // the math — a shadow built by the pool and one built under the
    // serial scope must be bit-identical for any CELER_NUM_THREADS (CI
    // runs this at 1 and 4 threads), on dense and sparse storage.
    use celer::data::shadow::ShadowF32;
    for x in [&big_dense(15), &big_sparse(15)] {
        let pooled = x.shadow_f32();
        let serial = par::run_serial(|| x.shadow_f32());
        let v: Vec<f32> = rand_vec(16, x.n()).iter().map(|&t| t as f32).collect();
        let lanes = [0usize];
        for j in (0..x.p()).step_by(97) {
            assert_eq!(
                pooled.col_dot(j, &v).to_bits(),
                serial.col_dot(j, &v).to_bits(),
                "shadow col_dot j={j}"
            );
            let mut op = [0.0f32];
            let mut os = [0.0f32];
            pooled.col_dot_lanes(j, &v, x.n(), &lanes, &mut op);
            serial.col_dot_lanes(j, &v, x.n(), &lanes, &mut os);
            assert_eq!(op[0].to_bits(), os[0].to_bits(), "shadow lane dot j={j}");
        }
        // the explicit constructor path used by the out-of-core store
        if let DesignMatrix::Sparse(csc) = x {
            let (indptr, indices, data) = {
                let mut ip = vec![0usize; csc.p() + 1];
                let mut ix = Vec::new();
                let mut dv = Vec::new();
                for j in 0..csc.p() {
                    let (ci, cd) = csc.col(j);
                    ix.extend_from_slice(ci);
                    dv.extend(cd.iter().map(|&t| t as f32));
                    ip[j + 1] = ix.len();
                }
                (ip, ix, dv)
            };
            let parts = ShadowF32::sparse_from_parts(
                csc.n(),
                csc.p(),
                indptr.clone(),
                indices.clone(),
                data.clone(),
            );
            let parts_serial = par::run_serial(|| {
                ShadowF32::sparse_from_parts(csc.n(), csc.p(), indptr, indices, data)
            });
            for j in (0..csc.p()).step_by(97) {
                assert_eq!(
                    parts.col_dot(j, &v).to_bits(),
                    parts_serial.col_dot(j, &v).to_bits()
                );
                assert_eq!(
                    parts.col_dot(j, &v).to_bits(),
                    pooled.col_dot(j, &v).to_bits(),
                    "sparse_from_parts == from_csc shadow, j={j}"
                );
            }
        }
    }
}

#[test]
fn first_touch_primitives_have_plain_vec_semantics() {
    // alloc_first_touch must equal a plain sequential collect (the
    // worker that touches a shard changes page placement, never bits),
    // and resize_first_touch must equal Vec::resize, above and below
    // the parallel threshold and with fewer items than shards.
    for len in [0usize, 7, par::SHARDS + 5, par::PAR_WORK_THRESHOLD + 123] {
        let f = |i: usize| (i as u64).wrapping_mul(0x9E3779B97F4A7C15) as f64 * 1e-18;
        let pooled = par::alloc_first_touch(len, 1, f);
        let plain: Vec<f64> = (0..len).map(f).collect();
        assert_eq!(pooled, plain, "alloc len={len}");
        let serial = par::run_serial(|| par::alloc_first_touch(len, 1, f));
        assert_eq!(serial, plain, "serial alloc len={len}");

        let mut grown = plain.clone();
        par::resize_first_touch(&mut grown, len * 2 + 3);
        let mut expect = plain.clone();
        expect.resize(len * 2 + 3, 0.0);
        assert_eq!(grown, expect, "grow len={len}");
        par::resize_first_touch(&mut grown, len / 2);
        expect.truncate(len / 2);
        assert_eq!(grown, expect, "shrink len={len}");
    }
}

#[test]
fn solver_results_invariant_under_serial_scope() {
    // End-to-end: a full gap-certified solve driven through the pooled
    // scans equals the all-serial run bit-for-bit. With the CI thread
    // matrix (CELER_NUM_THREADS ∈ {1, 4}) this pins thread-count
    // invariance of gaps, dual points, and solutions.
    for x in [&big_dense(13), &big_sparse(13)] {
        let y = rand_vec(14, x.n());
        let lambda = celer::lasso::dual::lambda_max(x, &y) / 8.0;
        let cfg = celer::solvers::cd::CdConfig { tol: 1e-8, screen: true, ..Default::default() };
        let pooled = celer::solvers::cd::cd_solve(x, &y, lambda, None, &cfg);
        let serial = par::run_serial(|| celer::solvers::cd::cd_solve(x, &y, lambda, None, &cfg));
        assert_eq!(pooled.beta, serial.beta);
        assert_eq!(pooled.gap.to_bits(), serial.gap.to_bits());
        assert_eq!(pooled.epochs, serial.epochs);
        assert_eq!(pooled.theta, serial.theta);
    }
}
